"""Scaling formalisms walkthrough: all five formalisms evaluated and the joint
(N, S) exponent fit recovered from synthetic outcome data.

Run: PYTHONPATH=src python examples/scaling_formalisms.py
"""
import numpy as np

from repro.core import (CoverageParams, coverage, cost_total,
                        device_task_match, energy_total, fit_coverage_joint,
                        latency, samples_for_coverage)
from repro.core.devices import EDGE_CPU, EDGE_GPU_NVIDIA, EDGE_NPU

print("=== Formalism 1: coverage scaling ===")
p = CoverageParams.calibrated(124.0, target_cov=0.70)
for S in (1, 5, 20, 50):
    print(f"  C(S={S:3d}, GPT-2, T=256) = {coverage(S, 124, 256, p):.3f}")
print(f"  samples for 80% coverage: "
      f"{samples_for_coverage(0.80, 124, 256, p):.1f}")

print("\n=== Formalism 2: energy scaling (per device) ===")
for dev in (EDGE_CPU, EDGE_NPU, EDGE_GPU_NVIDIA):
    e = energy_total(20, 124, 256, "fp16", dev)
    e8 = energy_total(20, 124, 256, "fp8", dev)
    print(f"  {dev.name:28s}: {e:8.1f} J fp16, {e8:8.1f} J fp8")

print("\n=== Formalism 3: latency decomposition ===")
for dev in (EDGE_CPU, EDGE_GPU_NVIDIA):
    lb = latency(S=20, T=256, N=124e6, device=dev, heterogeneous=True)
    print(f"  {dev.name:28s}: prefill {lb.prefill_s * 1e3:7.2f} ms, "
          f"decode {lb.decode_s * 1e3:8.2f} ms, overhead "
          f"{lb.overhead_s * 1e3:.2f} ms")

print("\n=== Formalism 4: cost scaling ===")
c = cost_total(20, energy_joules=22500, device=EDGE_GPU_NVIDIA)
print(f"  amortization ${c['amortization']:.2e}, energy ${c['energy']:.4f}, "
      f"total ${c['total']:.4f} per workload")

print("\n=== Formalism 5: roofline device-task matching ===")
for intensity, stage in ((973, "prefill"), (2.1, "decode")):
    for dev in (EDGE_GPU_NVIDIA, EDGE_NPU):
        print(f"  {stage:8s} (I={intensity:6.1f}) on {dev.name:28s}: "
              f"{device_task_match(intensity, dev)} "
              f"(ridge {dev.ridge_point:.0f})")

print("\n=== Joint (N, S) exponent recovery ===")
true = CoverageParams(alpha=3e-4, beta_N=0.68, beta_S=0.73)
N, S, C = [], [], []
rng = np.random.default_rng(0)
for n in (125, 350, 500, 1236, 2600):
    for s in (1, 2, 5, 10, 20):
        N.append(n)
        S.append(s)
        C.append(coverage(s, n, 256, true) * (1 + 0.01 * rng.standard_normal()))
fit = fit_coverage_joint(N, S, C)
print(f"  true beta_N={true.beta_N}, beta_S={true.beta_S}")
print(f"  fit  beta_N={fit.beta_N:.3f}, beta_S={fit.beta_S:.3f}, "
      f"R2={fit.r2:.4f}")
