"""DeltaEvaluator: incremental plan costing must match the full
`plan_costs` path to 1e-9 relative over arbitrary move sequences, revert
bit-exactly, and make incremental PGSAM anneals agree with the full-path
annealer's contract."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import Constraints, Workload, decompose, plan_costs
from repro.core.devices import (EDGE_CPU, EDGE_GPU_NVIDIA, EDGE_NPU,
                                EDGE_PLATFORM)
from repro.models import ArchConfig
from repro.qeil2 import DeltaEvaluator, PGSAMConfig, PGSAMOrchestrator

TINY = ArchConfig(name="tiny", arch_type="dense", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=1000)
MED = ArchConfig(name="med-12l", arch_type="dense", n_layers=12, d_model=256,
                 n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=1000)
SMALL_W = Workload(batch=1, prompt_tokens=32, decode_tokens=32, samples=4)
UNCONSTRAINED = Constraints(latency_budget_factor=None)
REL = 1e-9


def _full_objectives(stages, devices, mapping, model, temps=None,
                     workload=SMALL_W):
    assign = {st.name: devices[di] for st, di in zip(stages, mapping)}
    costs = plan_costs(stages, assign, "bf16", workload, model=model,
                       temps=temps)
    per = costs.per_device_time()
    busy = sum(per.values())
    mk = costs.makespan_s
    underutil = 1.0 - busy / (len(devices) * mk) if mk > 0 else 0.0
    return (costs.energy_j, mk, underutil)


def _assert_matches(got, want):
    for g, w in zip(got, want):
        assert g == pytest.approx(w, rel=REL)


@pytest.mark.parametrize("model", ["v1", "v2"])
def test_parity_over_seeded_move_sequence(model):
    """Acceptance: incremental costs == full plan_costs to 1e-9 after every
    move of a randomized sequence (both energy models)."""
    stages = decompose(MED, SMALL_W)
    devices = EDGE_PLATFORM
    temps = {EDGE_GPU_NVIDIA.name: 83.0} if model == "v2" else None
    rng = np.random.default_rng(0)
    mapping = list(rng.integers(0, len(devices), len(stages)))
    ev = DeltaEvaluator(stages, devices, mapping, "bf16", SMALL_W,
                        model=model, temps=temps)
    _assert_matches(ev.objectives(),
                    _full_objectives(stages, devices, mapping, model, temps))
    for _ in range(120):
        si = int(rng.integers(len(stages)))
        di = int(rng.integers(len(devices)))
        ev.apply(si, di)
        mapping[si] = di
        _assert_matches(
            ev.objectives(),
            _full_objectives(stages, devices, mapping, model, temps))


@given(seed=st.integers(0, 2 ** 16), n_moves=st.integers(1, 60))
@settings(max_examples=25, deadline=None)
def test_parity_randomized_hypothesis(seed, n_moves):
    """Property form of the parity contract (hypothesis-gated via
    tests/_hypothesis_compat.py): any move sequence, v2 model with a hot
    device, 1e-9 relative."""
    stages = decompose(TINY, SMALL_W)
    devices = [EDGE_CPU, EDGE_NPU, EDGE_GPU_NVIDIA]
    temps = {EDGE_NPU.name: 71.0}
    rng = np.random.default_rng(seed)
    mapping = list(rng.integers(0, len(devices), len(stages)))
    ev = DeltaEvaluator(stages, devices, mapping, "bf16", SMALL_W,
                        model="v2", temps=temps)
    for _ in range(n_moves):
        si = int(rng.integers(len(stages)))
        di = int(rng.integers(len(devices)))
        ev.apply(si, di)
        mapping[si] = di
    _assert_matches(ev.objectives(),
                    _full_objectives(stages, devices, mapping, "v2", temps))


def test_revert_is_bit_exact():
    stages = decompose(TINY, SMALL_W)
    devices = EDGE_PLATFORM
    rng = np.random.default_rng(3)
    mapping = list(rng.integers(0, len(devices), len(stages)))
    ev = DeltaEvaluator(stages, devices, mapping, "bf16", SMALL_W,
                        model="v2")
    before = ev.objectives()
    for _ in range(50):
        si = int(rng.integers(len(stages)))
        di = int(rng.integers(len(devices)))
        assert ev.peek(si, di) is not None
    assert ev.objectives() == before           # exact, not approx
    assert list(ev.mapping) == list(mapping)


def test_peek_equals_apply_then_objectives():
    stages = decompose(TINY, SMALL_W)
    devices = [EDGE_NPU, EDGE_GPU_NVIDIA]
    ev = DeltaEvaluator(stages, devices, [0] * len(stages), "bf16", SMALL_W,
                        model="v2")
    peeked = ev.peek(1, 1)
    ev.apply(1, 1)
    assert peeked == ev.objectives()


def test_move_fits_tracks_destination_capacity():
    stages = decompose(TINY, SMALL_W)
    small = EDGE_NPU.with_overrides(mem_cap=stages[0].param_bytes * 2)
    devices = [EDGE_GPU_NVIDIA, small]
    ev = DeltaEvaluator(stages, devices, [0] * len(stages), "bf16", SMALL_W)
    cap = small.mem_cap * 0.9
    assert ev.move_fits(0, 1, cap)
    ev.apply(0, 1)
    # second embed-sized stage overflows the shrunken device's headroom
    big = max(range(len(stages)), key=lambda i: stages[i].param_bytes)
    assert not ev.move_fits(big, 1, cap)


def test_unknown_model_rejected():
    stages = decompose(TINY, SMALL_W)
    with pytest.raises(ValueError):
        DeltaEvaluator(stages, EDGE_PLATFORM, [0] * len(stages),
                       model="v3")


# --------------------------------------------------- PGSAM incremental flag

def test_incremental_pgsam_fills_archive_costs():
    orch = PGSAMOrchestrator(
        EDGE_PLATFORM, UNCONSTRAINED,
        config=PGSAMConfig(seed=0, iters_max=400, incremental=True))
    a = orch.assign(TINY, SMALL_W)
    assert a.mapping and a.costs is not None
    assert all(e.costs is not None for e in orch.last_result.archive)
    # archive objectives are the exact full-path numbers after the fill
    for e in orch.last_result.archive:
        assert e.objectives[0] == pytest.approx(e.costs.energy_j, rel=1e-12)


def test_incremental_pgsam_not_worse_than_greedy_seed():
    from repro.core import GreedyOrchestrator
    devices = [EDGE_NPU, EDGE_GPU_NVIDIA]
    greedy = GreedyOrchestrator(devices, UNCONSTRAINED).assign(TINY, SMALL_W)
    inc = PGSAMOrchestrator(
        devices, UNCONSTRAINED,
        config=PGSAMConfig(seed=0, incremental=True)).assign(TINY, SMALL_W)
    assert inc.energy_j <= greedy.energy_j * (1 + 1e-9)


def test_incremental_pgsam_deterministic():
    runs = []
    for _ in range(2):
        orch = PGSAMOrchestrator(
            EDGE_PLATFORM, UNCONSTRAINED,
            config=PGSAMConfig(seed=11, iters_max=500, incremental=True))
        a = orch.assign(TINY, SMALL_W)
        runs.append((a.energy_j, a.latency_s,
                     tuple(sorted((k, v.name) for k, v in a.mapping.items()))))
    assert runs[0] == runs[1]


def test_incremental_pgsam_respects_memory():
    tiny_mem = EDGE_NPU.with_overrides(mem_cap=1e6)
    orch = PGSAMOrchestrator(
        [tiny_mem, EDGE_GPU_NVIDIA], UNCONSTRAINED,
        config=PGSAMConfig(seed=0, iters_max=300, incremental=True))
    a = orch.assign(TINY, SMALL_W)
    stages = {s.name: s for s in decompose(TINY, SMALL_W)}
    used = {}
    for name, dev in a.mapping.items():
        used[dev.name] = used.get(dev.name, 0.0) + stages[name].param_bytes
    assert used.get(tiny_mem.name, 0.0) <= tiny_mem.mem_cap * 0.9 + 1
