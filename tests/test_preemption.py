"""Preemptive serving under faults (PR 10 robustness contract).

* Decode-boundary preemption: a tier-outranked pipeline-tail batch is cut,
  its per-request state snapshots into `ResumeState`, and the merged final
  result is token-identical to an uninterrupted run (the stub backend's
  token stream is a pure function of history length, so splicing errors
  cannot hide).
* push_front fairness: a preempted request keeps its original arrival/seq —
  its completed ``queue_delay_s`` reflects TOTAL wall time.
* Lifecycle policies: per-tier deadlines cancel overdue queued work, fault
  evictions retry with exponential backoff (and cancel past the budget),
  queue-depth / KV-watermark load shedding drops oldest-economy-first.
* DriftEvent wiring: ``device_failed`` preempts in-flight batches routed
  onto the dead device; ``kv_squeeze`` / ``slow_kernel`` adjust admission
  and service-time state; the chaos harness replays a seeded `FaultPlan`
  through the real `SafetyMonitor` bus.
* Real-backend guarantees (JAX): hypothesis-driven allocator invariants
  (``in_use + free == total`` under random preempt/resume/cancel/fault
  interleavings, zero refcount leaks after drain), bit-parity of a
  preempted-then-resumed greedy request against an uninterrupted run
  (dense and paged+pooled, with and without speculative decode), and
  chunked-prefill bit-parity against the one-shot prefill.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.devices import EDGE_PLATFORM
from repro.core.safety import DriftEvent, SafetyMonitor
from repro.models import ArchConfig
from repro.qeil2 import SLATier, merge_tiers
from repro.serving import (ContinuousBatchingScheduler, SchedulerConfig,
                           tier_priority)
from repro.serving.chaos import ChaosDriver, FaultAction, FaultPlan, attach

# ------------------------------------------------------------------- stubs


class _Handle:
    """Deterministic stream: a row with history length L emits tokens
    L, L+1, ... — a pure function of history, so a preempted-then-resumed
    request reproduces the uninterrupted stream exactly iff the scheduler's
    snapshot/merge bookkeeping is right."""

    def __init__(self, prompts, repeats, max_new):
        self.prompts = [np.asarray(p) for p in prompts]
        self.repeats = list(repeats)
        self.plen = len(prompts[0])
        self.max_new = max_new
        self.spec = None
        self.row_plens = [len(p) for p, k in zip(prompts, repeats)
                          for _ in range(k)]
        self.step = 1                       # first token sampled at prefill
        self.out_toks = [np.asarray(self.row_plens, np.int64)]
        self.out_lps = [np.full(len(self.row_plens), -0.5)]

    @property
    def n_sequences(self):
        return sum(self.repeats)

    @property
    def done(self):
        return self.step >= self.max_new


class _PreemptBackend:
    """Policy double with the release contract preemption needs (the plain
    scheduler stub has no ``release``, which auto-disables preemption)."""

    def __init__(self, max_slots=None):
        self.max_slots = max_slots
        self.slots_in_use = 0
        self.batches = []
        self.released = []
        self._live = {}

    @property
    def slots_free(self):
        if self.max_slots is None:
            return None
        return self.max_slots - self.slots_in_use

    def note_placement(self, placement):
        pass

    def start_batch(self, prompts, n_samples, max_new, temperature, rng,
                    extras=None):
        plens = [len(p) for p in prompts]
        assert len(set(plens)) == 1, "backend got a mixed-bucket batch"
        h = _Handle(list(prompts), list(n_samples), max_new)
        self.slots_in_use += h.n_sequences
        self._live[id(h)] = h
        self.batches.append((plens, list(n_samples)))
        return h

    def decode_step(self, h):
        h.out_toks.append(np.asarray([pl + h.step for pl in h.row_plens],
                                     np.int64))
        h.out_lps.append(np.full(len(h.row_plens), -0.5))
        h.step += 1
        return not h.done

    def release(self, h):
        if self._live.pop(id(h), None) is None:
            raise RuntimeError("double release")
        self.slots_in_use -= h.n_sequences
        self.released.append(h)

    def finalize(self, h):
        self.release(h)
        toks = np.stack(h.out_toks, axis=1)        # (B, T)
        out, off = [], 0
        for p, k in zip(h.prompts, h.repeats):
            out.append(SimpleNamespace(
                prompt=p, samples=[toks[off + i] for i in range(k)],
                logprobs=[-0.5] * k))
            off += k
        return out


class _StubRouter:
    def __init__(self, tiers, base_latency_s=1.0, per_request_s=0.25,
                 device=None):
        self.tiers = {t.name: t for t in tiers}
        self.base = base_latency_s
        self.per_request = per_request_s
        self.device = device               # stamps assignment.device_names

    def resolve_tier(self, tier):
        return self.tiers[tier] if isinstance(tier, str) else tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, **kw):
        members = [self.resolve_tier(t) for t in tiers]
        assignment = object()
        if self.device is not None:
            dev = self.device
            assignment = SimpleNamespace(device_names=lambda: [dev])
        return SimpleNamespace(
            tier=merge_tiers(members), tier_counts={},
            assignment=assignment, point_index=0, meets_caps=True,
            batch_costs=None, energy_j=1.0 * len(members),
            latency_s=self.base + self.per_request * len(members), notes=[])


def _tiers3(p99=None):
    return [SLATier("interactive", latency_p99_s=p99,
                    energy_weight=0.0, latency_weight=1.0),
            SLATier("standard", energy_weight=0.5, latency_weight=0.5),
            SLATier("economy", energy_weight=1.0, latency_weight=0.0)]


def _prompt(n, mult=1):
    return (mult * np.arange(1, n + 1, dtype=np.int32)) % 61


def _expected_tokens(plen, max_new):
    """The stub stream an uninterrupted request emits."""
    return np.arange(plen, plen + max_new, dtype=np.int64)


def _sched(preempt=True, max_slots=None, device=None, obs=None, **cfg_kw):
    cfg_kw.setdefault("max_batch_requests", 2)
    cfg_kw.setdefault("max_inflight_batches", 1)
    cfg_kw.setdefault("max_new_tokens", 8)
    backend = _PreemptBackend(max_slots=max_slots)
    sched = ContinuousBatchingScheduler(
        backend, _StubRouter(_tiers3(), device=device),
        SchedulerConfig(preempt=preempt, **cfg_kw), obs=obs)
    return sched, backend


# --------------------------------------------------- tier preemption (stub)

def test_interactive_cuts_economy_and_both_streams_survive():
    sched, backend = _sched()
    adm_e = sched.submit(_prompt(8), tier="economy")
    sched.step()                           # economy enters service
    sched.step()                           # one more decode step
    assert len(sched.inflight) == 1
    econ_done_t = sched.inflight[0].done_t
    adm_i = sched.submit(_prompt(6), tier="interactive")
    sched.run_until_idle()

    assert sched.preemptions == {"tier": 1}
    assert set(sched.completed) == {adm_e.request_id, adm_i.request_id}
    # interactive was served at the preemption instant, ahead of the
    # victim's original completion (that's the entire point of the cut)
    irec = next(r for r in sched.records if r.tier_mix == {"interactive": 1})
    assert irec.t_s < econ_done_t
    # the victim's merged stream is exactly the uninterrupted one
    res = sched.completed[adm_e.request_id].result
    np.testing.assert_array_equal(res.samples[0], _expected_tokens(8, 8))
    assert res.logprobs[0] == pytest.approx(-0.5)
    # the resumed batch re-prefilled the snapshot history (no pool on the
    # stub, so tail == full)
    rrec = next(r for r in sched.records if r.resume_requests)
    assert rrec.resume_full_tokens == rrec.resume_tail_tokens > 8
    assert not backend._live                # nothing leaked


def test_preempted_multisample_request_merges_every_sample():
    sched, _ = _sched(max_batch_requests=1)
    adm = sched.submit(_prompt(8), tier="economy", n_samples=3)
    sched.step()
    sched.preempt(sched.inflight[0], "tier")
    sched.run_until_idle()
    res = sched.completed[adm.request_id].result
    assert len(res.samples) == 3
    for s in res.samples:
        np.testing.assert_array_equal(s, _expected_tokens(8, 8))


def test_economy_waiter_never_preempts_interactive():
    sched, _ = _sched()
    sched.submit(_prompt(8), tier="interactive")
    sched.step()
    sched.submit(_prompt(6), tier="economy")
    sched.run_until_idle()
    assert sched.preemptions == {}


def test_preempt_off_runs_to_completion():
    sched, _ = _sched(preempt=False)
    sched.submit(_prompt(8), tier="economy")
    sched.step()
    sched.submit(_prompt(6), tier="interactive")
    sched.run_until_idle()
    assert sched.preemptions == {}
    assert len(sched.completed) == 2


def test_preemption_cap_is_a_no_starvation_bound():
    sched, _ = _sched(preempt_max_per_request=1, max_new_tokens=8)
    adm_e = sched.submit(_prompt(8), tier="economy")
    sched.step()
    sched.submit(_prompt(6), tier="interactive")
    sched.step()                           # preemption #1 fires
    assert sched.preemptions == {"tier": 1}
    # economy resumes; a second interactive may NOT cut it again
    while not any(r.resume_requests for r in sched.records):
        sched.step()
    sched.submit(_prompt(6, mult=2), tier="interactive")
    sched.run_until_idle()
    assert sched.preemptions == {"tier": 1}
    assert sched.completed[adm_e.request_id].request.preemptions == 1
    assert len(sched.completed) == 3


def test_preemption_rolls_back_the_pipeline_tail():
    sched, _ = _sched()
    sched.submit(_prompt(8), tier="economy")
    sched.step()
    entry = sched.inflight[0]
    before = sched.pipeline_free_t
    assert before == entry.done_t
    sched.preempt(entry, "tier")
    assert sched.pipeline_free_t < before
    assert sched.pipeline_free_t == entry.record.preempted_t_s
    assert entry.record.preempted == "tier"


# ---------------------------------------------------- push_front fairness

def test_preempted_queue_delay_reflects_total_wall_time():
    """Regression (PR 10): push_front keeps the original arrival_s/seq, so
    a preempted request's completed queue_delay_s is measured from its
    FIRST submission — never from the re-queue instant."""
    sched, _ = _sched()
    adm_e = sched.submit(_prompt(8), tier="economy")     # arrival 0.0
    sched.step()
    sched.step()
    sched.submit(_prompt(6), tier="interactive")
    sched.run_until_idle()
    done = sched.completed[adm_e.request_id]
    assert done.request.arrival_s == 0.0
    resumed_start = next(r.t_s for r in sched.records if r.resume_requests)
    assert resumed_start > 0.0
    # delay == (second service start - ORIGINAL arrival), i.e. total wait
    assert done.queue_delay_s == pytest.approx(resumed_start)
    rrec = next(r for r in sched.records if r.resume_requests)
    assert rrec.request_entries[0]["resumed"] is True
    assert rrec.request_entries[0]["queue_delay_s"] == \
        pytest.approx(resumed_start)


# ------------------------------------------------ fault eviction + retries

def test_device_failure_preempts_and_retries_with_backoff():
    sched, backend = _sched(device="edge-npu", retry_backoff_s=0.125)
    adm = sched.submit(_prompt(8), tier="economy")
    sched.step()
    sched.on_drift(DriftEvent(0.5, "edge-npu", "device_failed"))
    assert not sched.inflight
    assert sched.preemptions == {"fault": 1}
    assert sched.retries_total == 1
    assert "edge-npu" in sched._failed_devices
    req = next(iter(r for q in sched.queue._buckets.values() for r in q))
    t_p = sched.records[0].preempted_t_s
    assert req.not_before_s == pytest.approx(t_p + 0.125)
    # idle backoff: the drain jumps the sim clock to the retry instant
    sched.run_until_idle()
    assert sched.clock >= req.not_before_s
    res = sched.completed[adm.request_id].result
    np.testing.assert_array_equal(res.samples[0], _expected_tokens(8, 8))
    assert not backend._live


def test_fault_backoff_is_exponential():
    sched, _ = _sched(device="edge-npu", retry_backoff_s=0.1,
                      max_retries=10)
    sched.submit(_prompt(8), tier="economy")
    gaps = []
    for _ in range(3):
        sched.step()
        while not sched.inflight:
            sched.step()
        sched.on_drift(DriftEvent(sched.clock, "edge-npu", "device_failed"))
        req = next(r for q in sched.queue._buckets.values() for r in q)
        gaps.append(req.not_before_s - sched.records[-1].preempted_t_s)
    assert gaps == pytest.approx([0.1, 0.2, 0.4])


def test_fault_retry_budget_exhaustion_cancels():
    sched, backend = _sched(device="edge-npu", max_retries=0)
    adm = sched.submit(_prompt(8), tier="economy")
    sched.step()
    sched.on_drift(DriftEvent(0.5, "edge-npu", "device_failed"))
    assert adm.request_id in sched.cancelled
    assert sched.cancelled[adm.request_id][1] == "retry_exhausted"
    assert sched.queue.pending == 0 and not sched.inflight
    assert not backend._live


def test_fault_leaves_unrelated_placements_alone():
    sched, _ = _sched(device="edge-npu")
    sched.submit(_prompt(8), tier="economy")
    sched.step()
    sched.on_drift(DriftEvent(0.5, "soc-gpu", "device_failed"))
    assert sched.inflight and sched.preemptions == {}
    sched.on_drift(DriftEvent(0.6, "soc-gpu", "device_recovered"))
    assert "soc-gpu" not in sched._failed_devices


def test_kv_squeeze_and_slow_kernel_state():
    sched, _ = _sched(max_slots=8)
    sched.on_drift(DriftEvent(0.0, "", "kv_squeeze", value=5.0))
    assert sched.kv_reserve == 5
    assert sched._capacity_free() == 3
    sched.on_drift(DriftEvent(0.1, "", "slow_kernel", value=2.0))
    sched.submit(_prompt(8), tier="economy")
    sched.step()
    entry = sched.inflight[0]
    assert entry.done_t - entry.start_t == \
        pytest.approx(2.0 * entry.decision.latency_s)
    sched.on_drift(DriftEvent(0.2, "", "kv_squeeze", value=0.0))
    sched.on_drift(DriftEvent(0.2, "", "slow_kernel", value=1.0))
    assert sched.kv_reserve == 0 and sched.latency_inflation == 1.0


# ------------------------------------------------------ lifecycle policies

def test_deadline_cancels_overdue_queued_requests():
    backend = _PreemptBackend()
    tiers = _tiers3(p99=1.0)
    sched = ContinuousBatchingScheduler(
        backend, _StubRouter(tiers),
        SchedulerConfig(max_batch_requests=1, max_inflight_batches=1,
                        max_new_tokens=8, deadline_factor=1.0))
    ids = [sched.submit(_prompt(8, mult=m + 1), tier="interactive").request_id
           for m in range(4)]
    sched.run_until_idle()
    # batch latency 1.25 > deadline 1.0: only the first request (served
    # immediately) completes; the queued rest expire once the clock passes
    assert set(sched.completed) == {ids[0]}
    assert sched.deadline_misses == 3
    assert all(sched.cancelled[i][1] == "deadline" for i in ids[1:])
    assert len(sched.completed) + len(sched.cancelled) == 4


def test_economy_is_deadline_exempt_without_a_cap():
    backend = _PreemptBackend()
    sched = ContinuousBatchingScheduler(
        backend, _StubRouter(_tiers3(p99=1.0)),
        SchedulerConfig(max_batch_requests=1, max_inflight_batches=1,
                        max_new_tokens=8, deadline_factor=1.0))
    ids = [sched.submit(_prompt(8, mult=m + 1), tier="economy").request_id
           for m in range(3)]
    sched.run_until_idle()
    assert set(sched.completed) == set(ids)
    assert sched.deadline_misses == 0


def test_queue_depth_shed_drops_oldest_economy_first():
    sched, _ = _sched(shed_queue_depth=2, max_batch_requests=2)
    ids = [sched.submit(_prompt(8, mult=m + 1), tier="economy").request_id
           for m in range(4)]
    keep = sched.submit(_prompt(6), tier="interactive").request_id
    sched.run_until_idle()
    assert sched.shed_total == 3
    assert set(sched.cancelled) == set(ids[:3])       # oldest economy first
    assert all(reason == "shed" for _, reason in sched.cancelled.values())
    assert keep in sched.completed and ids[3] in sched.completed


def test_kv_watermark_preempts_inflight_when_queue_is_empty():
    sched, backend = _sched(max_slots=4, shed_kv_free_frac=0.5,
                            max_batch_requests=1)
    adm = sched.submit(_prompt(8), tier="economy", n_samples=3)
    sched.step()                           # 3/4 slots in use, free=1 < 2
    sched.step()                           # watermark preempts the tail
    assert sched.preemptions.get("shed", 0) >= 1
    sched.run_until_idle()
    res = sched.completed[adm.request_id].result
    for s in res.samples:
        np.testing.assert_array_equal(s, _expected_tokens(8, 8))
    assert not backend._live


# ------------------------------------------------------- obs + chaos (stub)

def test_robustness_metrics_and_spans_are_emitted():
    from repro.obs import make_observability
    obs = make_observability()
    sched, _ = _sched(device="edge-npu", obs=obs)
    sched.submit(_prompt(8), tier="economy")
    sched.step()
    sched.step()
    sched.submit(_prompt(6), tier="interactive")
    sched.run_until_idle()
    sched.on_drift(DriftEvent(9.0, "edge-npu", "device_failed"))
    reg = obs.metrics
    assert reg.get("serving_preemptions_total").value(reason="tier") == 1
    assert reg.get("serving_resume_prefill_bytes_saved_total") is not None
    assert reg.get("serving_deadline_miss_total") is not None
    assert reg.get("serving_retries_total") is not None
    names = {s.name for s in obs.tracer.spans}
    assert {"preempt", "resume"} <= names
    pre = next(s for s in obs.tracer.spans if s.name == "preempt")
    assert pre.attrs["reason"] == "tier" and pre.request_id is not None


def test_cancel_spans_carry_the_reason():
    from repro.obs import make_observability
    obs = make_observability()
    sched, _ = _sched(device="edge-npu", max_retries=0, obs=obs)
    sched.submit(_prompt(8), tier="economy")
    sched.step()
    sched.on_drift(DriftEvent(0.5, "edge-npu", "device_failed"))
    spans = [s for s in obs.tracer.spans if s.name == "cancel"]
    assert spans and spans[0].attrs["reason"] == "retry_exhausted"


def test_fault_plan_roundtrip_and_determinism(tmp_path):
    devs = [d.name for d in EDGE_PLATFORM]
    p1 = FaultPlan.random(7, devs, horizon_s=10.0, n_failures=2, n_spikes=1,
                          kv_squeeze_blocks=16, slow_factor=1.5)
    p2 = FaultPlan.random(7, devs, horizon_s=10.0, n_failures=2, n_spikes=1,
                          kv_squeeze_blocks=16, slow_factor=1.5)
    assert p1.actions == p2.actions
    assert p1.actions == sorted(p1.actions, key=lambda a: a.t_s)
    path = str(tmp_path / "plan.json")
    p1.save(path)
    assert FaultPlan.load(path).actions == p1.actions
    with pytest.raises(ValueError):
        FaultAction(0.0, "meteor_strike")
    with pytest.raises(ValueError):
        FaultAction(0.0, "device_fail")    # needs a device


def test_chaos_driver_replays_through_the_safety_bus():
    dev = EDGE_PLATFORM[0].name
    safety = SafetyMonitor(EDGE_PLATFORM)
    sched, _ = _sched(device=dev)
    plan = FaultPlan(seed=3, actions=[
        FaultAction(0.2, "kv_squeeze", value=2.0),
        FaultAction(0.5, "device_fail", device=dev),
        FaultAction(1.5, "device_recover", device=dev),
        FaultAction(2.0, "slow_kernel", value=1.5),
    ])
    driver = attach(plan, safety, sched)
    assert isinstance(driver, ChaosDriver) and not driver.done
    adm = sched.submit(_prompt(8), tier="economy")
    sched.step()
    assert driver.apply_due(0.3)[0].kind == "kv_squeeze"
    assert sched.kv_reserve == 2
    fired = driver.apply_due(0.6)
    assert [a.kind for a in fired] == ["device_fail"]
    # the failure reached the scheduler over the REAL DriftEvent bus
    assert sched.preemptions == {"fault": 1}
    assert dev in sched._failed_devices
    assert dev not in safety.health.healthy_devices()
    driver.apply_due(2.5)
    assert driver.done
    assert dev not in sched._failed_devices
    assert sched.latency_inflation == 1.5
    sched.run_until_idle()
    assert adm.request_id in sched.completed


# ===================================================== real-backend (JAX)

jax = pytest.importorskip("jax")
import jax.numpy as jnp                                         # noqa: E402

from repro.models import Model                                  # noqa: E402
from repro.spec import make_draft_policy                        # noqa: E402

CFG = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
BS = 4


@pytest.fixture(scope="module")
def model_params():
    model = Model(CFG, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    return model, params


def _real_backend(model_params, kind, spec=False, prefill_chunk=None):
    from repro.serving import ExecutionBackend
    model, params = model_params
    kw = {}
    if spec:
        kw = dict(spec_policy=make_draft_policy("ngram"), spec_n=2)
    if kind == "dense":
        return ExecutionBackend(model, params, max_slots=8, **kw)
    assert kind == "pooled"
    return ExecutionBackend(model, params, kv_blocks=96, kv_block_size=BS,
                            kv_pool=True, prefill_chunk=prefill_chunk, **kw)


def _real_sched(backend, device=None, **cfg_kw):
    cfg_kw.setdefault("max_batch_requests", 2)
    cfg_kw.setdefault("max_inflight_batches", 1)
    return ContinuousBatchingScheduler(
        backend, _StubRouter(_tiers3(), device=device),
        SchedulerConfig(preempt=True, max_new_tokens=6, temperature=0.0,
                        **cfg_kw))


def _result_tokens(res):
    return [np.asarray(s) for s in res.samples]


def _assert_results_identical(got, want):
    assert len(got.samples) == len(want.samples)
    for g, w in zip(_result_tokens(got), _result_tokens(want)):
        np.testing.assert_array_equal(g, w)
    for g, w in zip(got.logprobs, want.logprobs):
        assert g == pytest.approx(w, rel=1e-5, abs=1e-6)


# --------------------------------------------- preempt/resume bit parity

@pytest.mark.parametrize("kind,spec", [("dense", False), ("pooled", False),
                                       ("dense", True), ("pooled", True)])
def test_preempted_resume_matches_uninterrupted_greedy(model_params, kind,
                                                       spec):
    prompt = _prompt(8)
    base = _real_sched(_real_backend(model_params, kind, spec=spec))
    adm = base.submit(prompt, tier="economy", max_new_tokens=6)
    base.run_until_idle()
    want = base.completed[adm.request_id].result

    sched = _real_sched(_real_backend(model_params, kind, spec=spec))
    adm2 = sched.submit(prompt, tier="economy", max_new_tokens=6)
    sched.step()                           # prefill + first decode boundary
    assert sched.inflight
    sched.preempt(sched.inflight[0], "tier")
    sched.run_until_idle()
    got = sched.completed[adm2.request_id].result
    _assert_results_identical(got, want)
    assert sched.preemptions == {"tier": 1}
    if kind == "pooled":
        # the parked chain came back as a trie hit: the resume prefilled
        # strictly less than a pool-less re-prefill would have
        assert 0 < sched.resume_tail_tokens < sched.resume_full_tokens


def test_preempted_multisample_resume_matches_uninterrupted(model_params):
    prompt = _prompt(9)
    base = _real_sched(_real_backend(model_params, "pooled"))
    adm = base.submit(prompt, tier="economy", n_samples=2, max_new_tokens=6)
    base.run_until_idle()
    want = base.completed[adm.request_id].result

    sched = _real_sched(_real_backend(model_params, "pooled"))
    adm2 = sched.submit(prompt, tier="economy", n_samples=2,
                        max_new_tokens=6)
    sched.step()
    sched.preempt(sched.inflight[0], "tier")
    sched.run_until_idle()
    _assert_results_identical(sched.completed[adm2.request_id].result, want)


# ----------------------------------------------- chunked prefill parity

@pytest.mark.parametrize("chunk", [3, 4, 16])
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_chunked_prefill_is_bit_identical(model_params, chunk, temperature):
    def run(prefill_chunk):
        be = _real_backend(model_params, "pooled",
                           prefill_chunk=prefill_chunk)
        out = []
        for prompts in ([_prompt(9)], [_prompt(13), _prompt(13, mult=2)]):
            h = be.start_batch(prompts, [2] * len(prompts), 5, temperature,
                               jax.random.key(3))
            steps = 0
            while be.decode_step(h):
                steps += 1
                assert steps < 100
            out.append(be.finalize(h))
        assert be.allocator.blocks_in_use == be.prefix_pool.blocks_resident
        return out

    want, got = run(None), run(chunk)
    for wb, gb in zip(want, got):
        for w, g in zip(wb, gb):
            _assert_results_identical(g, w)


def test_chunked_prefill_requires_paged(model_params):
    from repro.serving import ExecutionBackend
    model, params = model_params
    with pytest.raises(ValueError):
        ExecutionBackend(model, params, max_slots=4, prefill_chunk=4)
    with pytest.raises(ValueError):
        ExecutionBackend(model, params, kv_blocks=32, kv_block_size=BS,
                         prefill_chunk=0)


def test_scheduler_interleaves_chunked_prefill(model_params):
    """A chunked-prefill batch spends extra decode_step calls in prefill;
    output still matches the unchunked scheduler run bitwise."""
    want_s = _real_sched(_real_backend(model_params, "pooled"))
    a1 = want_s.submit(_prompt(13), tier="economy", max_new_tokens=5)
    want_s.run_until_idle()

    got_s = _real_sched(_real_backend(model_params, "pooled",
                                      prefill_chunk=3))
    a2 = got_s.submit(_prompt(13), tier="economy", max_new_tokens=5)
    got_s.run_until_idle()
    _assert_results_identical(got_s.completed[a2.request_id].result,
                              want_s.completed[a1.request_id].result)


# ------------------------------------------- allocator invariants (chaos)

def _check_alloc(backend):
    alloc = backend.allocator
    free = set(alloc._free)
    assert len(free) == len(alloc._free)           # no duplicate free entries
    assert not free & set(alloc._ref)              # free xor referenced
    # every non-free block is tracked with a positive refcount: in_use +
    # free == total with zero untracked ("leaked") blocks
    assert len(alloc._ref) + len(free) == alloc.n_blocks
    assert all(v >= 1 for v in alloc._ref.values())


@settings(max_examples=8, deadline=None)
@given(st.lists(st.sampled_from(
    ["submit", "step", "step", "fault", "preempt", "shed"]),
    min_size=4, max_size=14))
def test_allocator_invariants_under_preempt_resume_cancel_fault(
        model_params, ops):
    """The PR 10 robustness invariant: random interleavings of submission,
    service, tier preemption, device faults and shedding never break
    ``in_use + free == total``, and a full drain leaves zero refcount leaks
    (everything still allocated is trie-resident, by exactly one ref)."""
    backend = _real_backend(model_params, "pooled")
    sched = _real_sched(backend, device="edge-npu", max_inflight_batches=2,
                        retry_backoff_s=0.01, max_retries=10)
    submitted = []
    for i, op in enumerate(ops):
        if op == "submit":
            adm = sched.submit(_prompt(6, mult=(i % 3) + 1),
                               tier=("interactive" if i % 2 else "economy"),
                               max_new_tokens=4)
            assert adm.admitted
            submitted.append(adm.request_id)
        elif op == "step":
            sched.step()
        elif op == "fault":
            sched.on_drift(DriftEvent(sched.clock, "edge-npu",
                                      "device_failed"))
            sched.on_drift(DriftEvent(sched.clock, "edge-npu",
                                      "device_recovered"))
        elif op == "preempt" and sched.inflight:
            sched.preempt(sched.inflight[-1], "tier")
        elif op == "shed" and sched.queue.pending:
            victim = sched.queue.shed_oldest(tier_priority)
            sched._cancel(victim, "shed")
        _check_alloc(backend)
    sched.run_until_idle()
    _check_alloc(backend)
    # zero lost: every admitted request either completed or was cancelled
    # with a recorded reason
    assert set(submitted) == set(sched.completed) | set(sched.cancelled)
    # zero leaks: no live handles; every still-allocated block is held by
    # the prefix trie (refcount exactly 1 — the trie's)
    assert not backend._live
    alloc = backend.allocator
    assert alloc.blocks_in_use == backend.prefix_pool.blocks_resident
    assert all(ref == 1 and alloc.protected_owner(b) is not None
               for b, ref in alloc._ref.items())
