"""End-to-end kernel integration: Model(use_kernel=True) routes prefill
through the flash-attention Pallas kernel and decode through the
decode-attention kernel (interpret mode on CPU) and must match the jnp path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ArchConfig, Model

CFG = ArchConfig(name="k", arch_type="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97)
CFG_WIN = CFG.with_overrides(attn_window=8, name="kw")


@pytest.mark.parametrize("cfg", [CFG, CFG_WIN], ids=["full", "window"])
def test_kernel_model_matches_reference(cfg):
    ref_model = Model(cfg, dtype=jnp.float32, use_kernel=False)
    k_model = Model(cfg, dtype=jnp.float32, use_kernel=True)
    params = ref_model.init(jax.random.key(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, 97)

    lr, _, _ = ref_model.forward(params, {"tokens": toks})
    lk, _, _ = k_model.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lr),
                               rtol=2e-4, atol=2e-4)

    # prefill + decode chain through the kernels
    cache_r = ref_model.init_cache(B, S + 4)
    cache_k = k_model.init_cache(B, S + 4)
    _, cache_r, _ = ref_model.forward(params, {"tokens": toks}, cache_r)
    _, cache_k, _ = k_model.forward(params, {"tokens": toks}, cache_k)
    for step in range(3):
        nt = jax.random.randint(jax.random.key(5 + step), (B, 1), 0, 97)
        pos = jnp.full((B, 1), S + step, jnp.int32)
        lr, cache_r, _ = ref_model.forward(
            params, {"tokens": nt, "positions": pos}, cache_r)
        lk, cache_k, _ = k_model.forward(
            params, {"tokens": nt, "positions": pos}, cache_k)
        np.testing.assert_allclose(np.asarray(lk), np.asarray(lr),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"decode step {step}")
