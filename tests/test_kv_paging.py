"""Paged KV cache with prefix sharing (PR 5 tentpole).

* `BlockAllocator` invariants — no double-free, refcounts hit zero exactly
  once, blocks_in_use + blocks_free == total — deterministically and under
  hypothesis-driven random admit/fork/early-stop/release sequences;
* paged decode is *bit-identical* to the dense path (tokens + logprobs),
  including copy-on-write of a partially-filled prefix block and
  non-uniform per-prompt sample counts (the pinned acceptance parity);
* the paged Pallas kernel matches the gathered jnp oracle;
* `ExecutionBackend.release` raises on double release (regression: it used
  to silently drive the budget negative);
* extras are tiled once at prefill and reused across decode steps;
* scheduler admission prices requests in blocks at shared-prefix cost, and
  `early_stop` (CSVET) returns private blocks mid-flight;
* "serve" trace records carry KV block occupancy + prefill bytes saved.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.models import ArchConfig, Model  # noqa: E402
from repro.models.cache import (kv_bytes_per_token, make_cache,  # noqa: E402
                                PagedLayout, paged_supported)
from repro.serving import (BlockAllocator, ContinuousBatchingScheduler,  # noqa: E402
                           ExecutionBackend, SchedulerConfig, ServingEngine,
                           build_paged_layout)

CFG = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


@pytest.fixture(scope="module")
def model_params():
    model = Model(CFG, dtype=jnp.float32)
    return model, model.init(jax.random.key(0))


def _prompt(n, mult=1):
    return (np.arange(1, n + 1, dtype=np.int32) * mult) % CFG.vocab_size


# ========================================================== allocator (unit)

def test_allocator_alloc_fork_cow_free_lifecycle():
    a = BlockAllocator(4, 8)
    b0 = a.alloc()
    assert a.refcount(b0) == 1 and a.blocks_in_use == 1
    a.fork(b0)
    a.fork(b0)
    assert a.refcount(b0) == 3
    # shared -> cow copies and drops one reference
    c1, copied = a.cow(b0)
    assert copied and c1 != b0 and a.refcount(b0) == 2
    c2, copied = a.cow(b0)
    assert copied and c2 not in (b0, c1)
    # sole holder -> write in place
    c3, copied = a.cow(b0)
    assert not copied and c3 == b0
    assert a.blocks_in_use + a.blocks_free == a.n_blocks == 4
    # each holder frees once; block returns with its last reference
    assert a.free(c1) and a.free(c2)
    assert a.free(b0)
    assert a.blocks_free == 4


def test_allocator_double_free_and_exhaustion_raise():
    a = BlockAllocator(2, 4)
    b = a.alloc()
    a.free(b)
    with pytest.raises(RuntimeError, match="double free"):
        a.free(b)
    with pytest.raises(KeyError):
        a.fork(b)
    a.alloc()
    a.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        a.alloc()


def test_allocator_free_of_trie_resident_block_raises():
    """Regression (PrefixPool guard): dropping the *last* reference of a
    trie-resident block must raise, naming the block and its owning prefix
    — cached KV silently returning to the free list would corrupt the
    prefix index. Holder refs above the trie ref still release normally."""
    a = BlockAllocator(2, 4)
    b = a.alloc()                       # trie ref (pool insert forks+protects)
    a.protect(b, "depth 1, chunk tokens [5, 6, 7, 8]...")
    a.fork(b)                           # one live holder on top
    assert a.free(b) is False           # holder release: fine, ref 2 -> 1
    with pytest.raises(RuntimeError) as ei:
        a.free(b)                       # last ref is the trie's: hard error
    assert f"block {b}" in str(ei.value)
    assert "depth 1, chunk tokens [5, 6, 7, 8]" in str(ei.value)
    assert a.refcount(b) == 1           # nothing was released
    assert a.blocks_in_use == 1
    a.unprotect(b)                      # eviction path: unprotect, then free
    assert a.free(b)
    assert a.blocks_free == 2
    with pytest.raises(KeyError, match="unallocated"):
        a.protect(b, "stale")           # protection requires a live block


def _run_lifecycle(n_blocks, bs, requests, early, seed):
    """Drive build_paged_layout + early/final release over `requests`
    (plen, max_new, k) triples; checks the allocator invariants throughout.
    Returns the allocator for final assertions."""
    a = BlockAllocator(n_blocks, bs)
    rng = np.random.default_rng(seed)
    returned = {}                      # physical block -> times it came back
    live = []
    for (plen, max_new, k) in requests:
        n_logical = max(-(-(plen + max_new - 1) // bs), 1)
        need = plen // bs + k * (n_logical - plen // bs)
        if need > a.blocks_free:
            continue                   # admission would reject; skip
        layout = build_paged_layout(a, plen, max_new, [k])
        assert a.blocks_in_use + a.blocks_free == a.n_blocks
        assert layout.n_pool_blocks == need
        live.append((layout, set()))
    for layout, freed in live:
        n_seq = len(layout.seq_gids)
        for i in rng.permutation(n_seq)[: rng.integers(0, n_seq + 1)] \
                if early else []:
            for g in layout.seq_gids[i]:
                if a.free(g):
                    returned[g] = returned.get(g, 0) + 1
            freed.add(int(i))
        assert a.blocks_in_use + a.blocks_free == a.n_blocks
    for layout, freed in live:
        for i, gids in enumerate(layout.seq_gids):
            if i in freed:
                continue
            for g in gids:
                if a.free(g):
                    returned[g] = returned.get(g, 0) + 1
    assert a.blocks_free == a.n_blocks          # everything came back
    assert all(v == 1 for v in returned.values())   # ...exactly once
    return a


def test_allocator_lifecycle_deterministic():
    reqs = [(7, 6, 3), (8, 8, 4), (3, 2, 1), (12, 8, 2), (5, 9, 5)]
    _run_lifecycle(64, 4, reqs, early=False, seed=0)
    _run_lifecycle(64, 4, reqs, early=True, seed=1)
    _run_lifecycle(24, 4, reqs * 3, early=True, seed=2)   # exercises skips


@settings(max_examples=40, deadline=None)
@given(st.integers(8, 64), st.sampled_from([2, 4, 8]),
       st.lists(st.tuples(st.integers(1, 12), st.integers(1, 10),
                          st.integers(1, 5)), min_size=1, max_size=8),
       st.booleans(), st.integers(0, 10))
def test_allocator_invariants_property(n_blocks, bs, requests, early, seed):
    _run_lifecycle(n_blocks, bs, requests, early=early, seed=seed)


def test_request_blocks_matches_actual_allocation():
    model = Model(CFG, dtype=jnp.float32)
    be = ExecutionBackend(model, None, kv_blocks=64, kv_block_size=4)
    for plen, max_new, k in [(7, 6, 3), (8, 8, 1), (3, 2, 4), (4, 4, 2)]:
        a = BlockAllocator(64, 4)
        layout = build_paged_layout(a, plen, max_new, [k])
        assert a.blocks_in_use == be.request_blocks(plen, max_new, k)
        assert layout.n_pool_blocks == a.blocks_in_use
        # shared-prefix price is never above the dense-equivalent price
        dense_eq = k * -(-(plen + max_new) // 4)
        assert be.request_blocks(plen, max_new, k) <= dense_eq


# ===================================================== paged/dense parity

def _generate(backend, prompts, n_samples, max_new, seed):
    h = backend.start_batch(prompts, n_samples, max_new, 0.8,
                            jax.random.key(seed))
    while backend.decode_step(h):
        pass
    return backend.finalize(h), h


@pytest.mark.parametrize("n_samples,plen,max_new", [
    (3, 7, 6),        # partial prefix block -> CoW fan-out; padded tail
    (1, 8, 8),        # no sharing, block-aligned
    ([2, 3], 7, 5),   # non-uniform per-prompt sample counts
])
def test_paged_decode_bit_identical_to_dense(model_params, n_samples, plen,
                                             max_new):
    """Acceptance: paged decode (prefix sharing + CoW + block-table
    attention) is bit-identical to the dense path — tokens AND logprobs."""
    model, params = model_params
    prompts = [_prompt(plen), _prompt(plen, mult=3)]
    dense = ExecutionBackend(model, params)
    paged = ExecutionBackend(model, params, kv_blocks=64, kv_block_size=4)
    want, _ = _generate(dense, prompts, n_samples, max_new, seed=7)
    got, h = _generate(paged, prompts, n_samples, max_new, seed=7)
    for a, b in zip(want, got):
        assert len(a.samples) == len(b.samples)
        for s1, s2 in zip(a.samples, b.samples):
            np.testing.assert_array_equal(s1, s2)
        assert a.logprobs == b.logprobs
    # paged prefilled one row per prompt, not per sequence
    B = sum(n_samples) if isinstance(n_samples, list) else \
        n_samples * len(prompts)
    assert h.prefill_bytes_saved == \
        (B - len(prompts)) * plen * paged.kv_token_bytes
    assert paged.allocator.blocks_free == paged.allocator.n_blocks


def test_paged_engine_generate_matches_dense(model_params):
    """The blocking `ServingEngine.generate` path works unchanged over a
    paged backend and reproduces the dense engine exactly."""
    model, params = model_params
    prompts = [_prompt(6), _prompt(6, 5), _prompt(9)]   # two buckets
    e_dense = ServingEngine(model, params, max_new_tokens=4)
    e_paged = ServingEngine(model, params, max_new_tokens=4,
                            backend=ExecutionBackend(model, params,
                                                     kv_blocks=64,
                                                     kv_block_size=4))
    want = e_dense.generate(prompts, n_samples=2, rng=jax.random.key(3))
    got = e_paged.generate(prompts, n_samples=2, rng=jax.random.key(3))
    for a, b in zip(want, got):
        for s1, s2 in zip(a.samples, b.samples):
            np.testing.assert_array_equal(s1, s2)
        assert a.logprobs == b.logprobs


def test_engine_chunks_to_kv_budget(model_params):
    """The blocking engine must split a call that exceeds the KV budget
    into budget-sized batches instead of crashing (regression: the serve
    launcher with --kv-blocks below the whole call's need died in
    start_batch), and a single impossible request fails with a clear
    error."""
    model, params = model_params
    # per request: plen=8, max_new=4, k=2 -> 2 + 2*1 = 4 blocks; budget 10
    # fits 2 requests per chunk -> 4 requests = 2 chunks
    be = ExecutionBackend(model, params, kv_blocks=10, kv_block_size=4)
    engine = ServingEngine(model, params, max_new_tokens=4, backend=be)
    prompts = [_prompt(8, m) for m in (1, 3, 5, 7)]
    results = engine.generate(prompts, n_samples=2, rng=jax.random.key(0))
    assert len(results) == 4
    assert all(len(r.samples) == 2 for r in results)
    assert be.allocator.blocks_free == 10
    with pytest.raises(ValueError, match="KV budget"):
        # 2 + 12*1 = 14 blocks > 10: no chunking can make one request fit
        engine.generate([_prompt(8)], n_samples=12, rng=jax.random.key(0))
    # dense slot budgets chunk the same way
    engine_d = ServingEngine(model, params, max_new_tokens=4,
                             backend=ExecutionBackend(model, params,
                                                      max_slots=4))
    results = engine_d.generate(prompts, n_samples=2, rng=jax.random.key(0))
    assert all(len(r.samples) == 2 for r in results)


def test_zero_sample_requests_rejected(model_params):
    """n_samples=0 would allocate prefix blocks no sequence references
    (an unreleasable leak) — rejected at every door."""
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=16, kv_block_size=4)
    with pytest.raises(ValueError, match=">= 1"):
        be.start_batch([_prompt(6)], 0, 4, 0.8, jax.random.key(0))
    with pytest.raises(ValueError, match=">= 1"):
        be.start_batch([_prompt(6)], [1, 0], 4, 0.8, jax.random.key(0))
    assert be.allocator.blocks_free == 16
    sched = ContinuousBatchingScheduler(
        be, _StubRouter(["economy"]),
        SchedulerConfig(max_batch_requests=4))
    with pytest.raises(ValueError, match=">= 1"):
        sched.submit(_prompt(6), tier="economy", n_samples=0)


def test_paged_kernel_matches_reference_model(model_params):
    """use_kernel=True routes paged decode through the Pallas block-table
    kernel; logits must match the gathered jnp reference path."""
    model, params = model_params
    kmodel = Model(CFG, dtype=jnp.float32, use_kernel=True)
    ref = ExecutionBackend(model, params, kv_blocks=32, kv_block_size=4)
    ker = ExecutionBackend(kmodel, params, kv_blocks=32, kv_block_size=4)
    prompts = [_prompt(7)]
    want, _ = _generate(ref, prompts, 2, 4, seed=11)
    got, _ = _generate(ker, prompts, 2, 4, seed=11)
    # sampling goes through identical logits up to kernel tolerance; with
    # the tiny vocab and fixed rng the argmax-ish picks coincide
    for a, b in zip(want, got):
        np.testing.assert_allclose(a.logprobs, b.logprobs, rtol=1e-4,
                                   atol=1e-4)


def test_paged_kernel_matches_ref_oracle():
    from repro.kernels.decode_attention.decode_attention import \
        paged_decode_attention_pallas
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref

    ks = jax.random.split(jax.random.key(0), 3)
    B, H, Hkv, D, P, bs, nb = 3, 4, 2, 16, 12, 4, 3
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (P, bs, Hkv, D), jnp.float32)
    vp = jax.random.normal(ks[2], (P, bs, Hkv, D), jnp.float32)
    table = jnp.asarray(np.random.default_rng(0).permutation(P)[: B * nb]
                        .reshape(B, nb), jnp.int32)
    q_pos = jnp.array([8, 5, 11], jnp.int32)
    pos = jnp.full((P, bs), -1, jnp.int32)
    for b in range(B):
        for j in range(nb):
            for r in range(bs):
                p_ = j * bs + r
                if p_ <= int(q_pos[b]):
                    pos = pos.at[table[b, j], r].set(p_)
    out = paged_decode_attention_pallas(q, kp, vp, pos, table, q_pos)
    ref = paged_decode_attention_ref(q, kp, vp, pos, table, q_pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_make_cache_rejects_unsupported_paged_archs():
    windowed = CFG.with_overrides(attn_window=8)
    assert not paged_supported(windowed)
    with pytest.raises(ValueError, match="paged"):
        make_cache(windowed, 1, 16, paged=PagedLayout(4, 4))
    with pytest.raises(ValueError, match="paged"):
        ExecutionBackend(Model(windowed, dtype=jnp.float32), None,
                         kv_blocks=8, kv_block_size=4)


# ============================================== release / early-stop / extras

def test_release_raises_on_double_release(model_params):
    """Regression: releasing a handle twice used to silently drive the
    budget negative; now it raises and the budget stays exact."""
    model, params = model_params
    for backend in (ExecutionBackend(model, params, max_slots=8),
                    ExecutionBackend(model, params, kv_blocks=32,
                                     kv_block_size=4)):
        results, h = _generate(backend, [_prompt(6)], 2, 3, seed=0)
        assert len(results) == 1
        with pytest.raises(RuntimeError, match="already-released"):
            backend.finalize(h)
        with pytest.raises(RuntimeError, match="already-released"):
            backend.release(h)
        assert backend.slots_in_use == 0
        if backend.allocator is not None:
            assert backend.allocator.blocks_free == backend.allocator.n_blocks
        with pytest.raises(RuntimeError, match="unknown"):
            backend.release(SimpleNamespace(paged=None, n_sequences=1,
                                            freed_seqs=set()))


def test_release_sequences_frees_blocks_mid_flight(model_params):
    """CSVET early stop: a sample's private blocks return to the budget
    immediately; shared prefix blocks only with their last holder; the
    final release does not double-free."""
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=64, kv_block_size=4)
    h = be.start_batch([_prompt(7)], 3, 6, 0.8, jax.random.key(1))
    in_use = be.allocator.blocks_in_use
    assert in_use == be.request_blocks(7, 6, 3)     # 1 + 3*2 = 7
    be.decode_step(h)
    # sample 0's privates (CoW partial + decode block) come back; the
    # full prefix block is still held by samples 1 and 2
    freed = be.release_sequences(h, [0])
    assert freed == 2
    assert be.allocator.blocks_in_use == in_use - 2
    # the budget frees before the memory does: the batch's pool array stays
    # resident until retirement
    assert be.pool_blocks_resident == in_use
    assert be.release_sequences(h, [0]) == 0        # idempotent per sample
    # releasing the rest returns everything, including the shared prefix
    assert be.release_sequences(h, [1, 2]) == 5
    assert be.allocator.blocks_free == be.allocator.n_blocks
    results = be.finalize(h)                        # no double-free
    assert len(results) == 1 and len(results[0].samples) == 3


def test_release_sequences_rejects_out_of_range_indices(model_params):
    """An out-of-range sequence index must raise, not silently release a
    neighbouring batch row's budget (dense) or crash mid-free (paged)."""
    model, params = model_params
    for backend in (ExecutionBackend(model, params, max_slots=8),
                    ExecutionBackend(model, params, kv_blocks=32,
                                     kv_block_size=4)):
        h = backend.start_batch([_prompt(6)], 2, 3, 0.8, jax.random.key(0))
        slots_before = backend.slots_in_use
        blocks_before = backend.blocks_in_use
        with pytest.raises(ValueError, match="out of range"):
            backend.release_sequences(h, [0, 5])
        assert backend.slots_in_use == slots_before       # nothing freed
        assert backend.blocks_in_use == blocks_before
        backend.finalize(h)


def test_scheduler_early_stop_rejects_out_of_range_samples(model_params):
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=32, kv_block_size=4)
    sched = ContinuousBatchingScheduler(
        be, _StubRouter(["economy"]),
        SchedulerConfig(max_batch_requests=4, max_new_tokens=4))
    adm = sched.submit(_prompt(6), tier="economy", n_samples=2)
    sched.step()
    with pytest.raises(ValueError, match="out of range"):
        sched.early_stop(adm.request_id, [2])   # request has samples 0..1
    sched.run_until_idle()


def test_failed_paged_prefill_returns_blocks(model_params, monkeypatch):
    """If anything after block allocation raises (OOM, bad extras), the
    layout's blocks must return to the budget — a failed start_batch must
    not permanently shrink the allocator."""
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=32, kv_block_size=4)

    def _boom(*a, **k):
        raise RuntimeError("simulated prefill failure")

    monkeypatch.setattr(be, "_prefill_jit", _boom)
    with pytest.raises(RuntimeError, match="simulated"):
        be.start_batch([_prompt(7)], 3, 6, 0.8, jax.random.key(0))
    assert be.allocator.blocks_free == 32


def test_extras_tiled_once_and_reused_across_decode_steps(model_params,
                                                          monkeypatch):
    """The per-request extras rows are tiled to the sequence count at
    prefill; decode steps must reuse the tiled arrays, not re-tile."""
    model, params = model_params
    be = ExecutionBackend(model, params)
    extras = {"bias": np.zeros((1, 3), np.float32)}
    h = be.start_batch([_prompt(6)], 3, 4, 0.8, jax.random.key(0), extras)
    tiled = {k: v for k, v in h.extras.items()}
    assert tiled["bias"].shape[0] == 3

    def _no_retile(*a, **k):
        raise AssertionError("decode_step must not re-tile extras")

    monkeypatch.setattr(jnp, "repeat", _no_retile)
    while be.decode_step(h):
        assert all(h.extras[k] is tiled[k] for k in tiled)
    be.finalize(h)


# ============================================= scheduler: blocks + telemetry

class _StubRouter:
    def __init__(self, tiers):
        self.tiers = {t: SimpleNamespace(name=t) for t in tiers}

    def resolve_tier(self, tier):
        return self.tiers[tier] if isinstance(tier, str) else tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, **kw):
        return SimpleNamespace(
            tier=self.resolve_tier(tiers[0]), tier_counts={},
            assignment=object(), point_index=0, meets_caps=True,
            batch_costs=None, energy_j=1.0, latency_s=1.0, notes=[])


def _paged_sched(model, params, kv_blocks, bs=4, max_batch=8):
    backend = ExecutionBackend(model, params, kv_blocks=kv_blocks,
                               kv_block_size=bs)
    return ContinuousBatchingScheduler(
        backend, _StubRouter(["economy"]),
        SchedulerConfig(max_batch_requests=max_batch, max_new_tokens=4)), \
        backend


def test_scheduler_admission_prices_blocks_at_shared_prefix(model_params):
    model, params = model_params
    # budget 12 blocks, bs=4: plen=8, max_new=8, k=4 costs 2 + 4*2 = 10
    # blocks at shared-prefix price — admitted; dense-equivalent would be
    # 4 * 4 = 16 and could never fit
    sched, backend = _paged_sched(model, params, kv_blocks=12)
    assert backend.request_blocks(8, 8, 4) == 10
    adm = sched.submit(_prompt(8), tier="economy", n_samples=4,
                       max_new_tokens=8)
    assert adm.admitted
    # a request over the total block budget is rejected at the door
    bad = sched.submit(_prompt(8), tier="economy", n_samples=6,
                       max_new_tokens=8)
    assert not bad.admitted and "exceeds the KV budget" in bad.reason
    sched.run_until_idle()
    assert adm.request_id in sched.completed
    assert backend.allocator.blocks_free == 12


def test_scheduler_batches_respect_block_budget(model_params):
    model, params = model_params
    sched, backend = _paged_sched(model, params, kv_blocks=16)
    # each request: plen=4, max_new=4, k=2 -> 1 + 2*1 = 3 blocks
    ids = [sched.submit(_prompt(4), tier="economy", n_samples=2,
                        max_new_tokens=4).request_id for _ in range(8)]
    high = 0
    while sched.queue.pending or sched.inflight:
        if not sched.step():
            break
        high = max(high, backend.allocator.blocks_in_use)
    assert high <= 16
    assert all(i in sched.completed for i in ids)
    assert backend.allocator.blocks_free == 16


def test_scheduler_early_stop_returns_blocks(model_params):
    model, params = model_params
    sched, backend = _paged_sched(model, params, kv_blocks=32)
    adm = sched.submit(_prompt(7), tier="economy", n_samples=3,
                       max_new_tokens=4)
    sched.step()                                    # prefill + first decode
    before = backend.allocator.blocks_free
    freed = sched.early_stop(adm.request_id, [1, 2])
    assert freed > 0
    assert backend.allocator.blocks_free == before + freed
    sched.run_until_idle()
    assert adm.request_id in sched.completed
    assert backend.allocator.blocks_free == 32
    # unknown / retired requests are a no-op
    assert sched.early_stop(adm.request_id) == 0


def test_serve_trace_records_carry_paging_fields(model_params):
    from repro.qeil2 import TraceStore

    model, params = model_params
    backend = ExecutionBackend(model, params, kv_blocks=32, kv_block_size=4)
    trace = TraceStore()
    sched = ContinuousBatchingScheduler(
        backend, _StubRouter(["economy"]),
        SchedulerConfig(max_batch_requests=4, max_new_tokens=3), trace=trace)
    sched.submit(_prompt(7), tier="economy", n_samples=3)
    sched.run_until_idle()
    [rec] = trace.records("serve")
    assert rec["kv_blocks_in_use"] == backend.request_blocks(7, 3, 3)
    assert rec["prefill_bytes_saved"] == \
        2 * 7 * kv_bytes_per_token(CFG, 4)
