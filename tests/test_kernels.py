"""Per-kernel validation (deliverable c): shape/dtype sweeps asserting
allclose against the pure-jnp ref.py oracles, in interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.decode_attention import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd_scan.ops import ssd_chunk
from repro.kernels.ssd_scan.ref import ssd_chunk_ref
from repro.models.ssm import ssd_chunked


# =============================================================== flash attn
FLASH_SHAPES = [
    # (B, Sq, Sk, H, Hkv, D, window)
    (1, 64, 64, 4, 4, 32, None),
    (2, 64, 64, 4, 2, 32, None),       # GQA
    (2, 64, 64, 4, 1, 32, None),       # MQA
    (1, 100, 100, 4, 4, 64, None),     # non-multiple of block
    (2, 33, 33, 8, 2, 16, None),
    (1, 128, 128, 2, 2, 64, 32),       # sliding window
    (2, 50, 50, 4, 2, 32, 8),
]


@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(shape, dtype):
    B, Sq, Sk, H, Hkv, D, window = shape
    ks = jax.random.split(jax.random.key(42), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=32, block_k=32)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@given(bq=st.sampled_from([16, 32, 64]), bk=st.sampled_from([16, 32, 64]))
@settings(max_examples=9, deadline=None)
def test_flash_attention_block_size_invariance(bq, bk):
    """Property: output must not depend on the BlockSpec tiling."""
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (1, 48, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 48, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 48, 2, 32), jnp.float32)
    out = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# =============================================================== decode attn
DECODE_SHAPES = [
    # (B, W, H, Hkv, D, filled, window)
    (2, 64, 4, 4, 32, 64, None),
    (2, 64, 4, 2, 32, 40, None),       # partially-filled cache
    (1, 100, 8, 2, 64, 77, None),
    (2, 64, 4, 2, 32, 64, 16),         # windowed
    (1, 32, 2, 1, 16, 5, None),        # nearly-empty cache
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(shape, dtype):
    B, W, H, Hkv, D, filled, window = shape
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, W, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, W, Hkv, D), dtype)
    pos = np.full((B, W), -1, np.int32)
    pos[:, :filled] = np.arange(filled)
    pos = jnp.asarray(pos)
    q_pos = jnp.full((B,), filled, jnp.int32)
    out = decode_attention_pallas(q, kc, vc, pos, q_pos, window=window,
                                  block_k=32)
    ref = decode_attention_ref(q, kc, vc, pos, q_pos, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_ring_semantics():
    """Slots hold out-of-order absolute positions (ring wraps): masking must
    follow positions, not slot order."""
    B, W, H, D = 1, 8, 2, 16
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kc = jax.random.normal(ks[1], (B, W, H, D))
    vc = jax.random.normal(ks[2], (B, W, H, D))
    # ring after 11 writes with W=8: slots hold positions [8,9,10,3,4,5,6,7]
    pos = jnp.asarray([[8, 9, 10, 3, 4, 5, 6, 7]], jnp.int32)
    q_pos = jnp.asarray([10], jnp.int32)
    out = decode_attention_pallas(q, kc, vc, pos, q_pos, window=4, block_k=8)
    ref = decode_attention_ref(q, kc, vc, pos, q_pos, window=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# =============================================================== ssd scan
SSD_SHAPES = [
    # (B, L, H, P, N, chunk)
    (2, 32, 2, 16, 16, 8),
    (1, 64, 4, 32, 64, 16),
    (2, 24, 3, 8, 16, 8),
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_kernel_matches_ref(shape):
    B, L, H, P, N, chunk = shape
    ks = jax.random.split(jax.random.key(11), 5)
    nc, Q = L // chunk, chunk
    x = jax.random.normal(ks[0], (B, nc, Q, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    dA = dt * A[None, None, None]
    dAcs = jnp.cumsum(dA, axis=2)
    Bm = jax.random.normal(ks[3], (B, nc, Q, H, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, nc, Q, H, N), jnp.float32)

    y_k, st_k = ssd_chunk(x, dt, dA, dAcs, Bm, Cm)

    def to_bh(a, width):
        return jnp.moveaxis(a, 3, 1).reshape((B * H, nc, Q, width))
    y_r, st_r = ssd_chunk_ref(to_bh(x, P), to_bh(dt[..., None], 1),
                              to_bh(dA[..., None], 1),
                              to_bh(dAcs[..., None], 1),
                              to_bh(Bm, N), to_bh(Cm, N))
    y_r = jnp.moveaxis(y_r.reshape(B, H, nc, Q, P), 1, 3)
    st_r = st_r.reshape(B, H, nc, P, N).transpose(0, 2, 1, 3, 4)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_end_to_end_in_chunked_scan():
    """use_kernel=True path of ssd_chunked must equal the jnp path."""
    B, L, H, P, N = 2, 32, 2, 16, 16
    ks = jax.random.split(jax.random.key(13), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, H, N))
    Cm = jax.random.normal(ks[4], (B, L, H, N))
    y0, s0 = ssd_chunked(x, dt, A, Bm, Cm, chunk=8, use_kernel=False)
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk=8, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-4, atol=1e-4)
