"""Beyond-paper decode optimizations must be exact (EXPERIMENTS.md §Perf):
cross-attention K/V caching and dense all-experts MoE decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ArchConfig, MoEConfig, Model


def test_cross_kv_cache_decode_matches_recompute():
    base = ArchConfig(name="a", arch_type="audio", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=33,
                      mlp_variant="gelu", rope_variant="sinusoidal",
                      n_codebooks=4, cross_attention=True, frontend="audio")
    cached = base.with_overrides(cross_kv_cache=True)
    m0 = Model(base, dtype=jnp.float32)
    m1 = Model(cached, dtype=jnp.float32)
    params = m0.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S, 4), 0, 33)
    mem = jax.random.normal(jax.random.key(2), (B, 8, 64)) * 0.1
    c0, c1 = m0.init_cache(B, S + 4), m1.init_cache(B, S + 4)
    _, c0, _ = m0.forward(params, {"tokens": toks, "cond_memory": mem}, c0)
    _, c1, _ = m1.forward(params, {"tokens": toks, "cond_memory": mem}, c1)
    for step in range(3):
        nt = jax.random.randint(jax.random.key(5 + step), (B, 1, 4), 0, 33)
        pos = jnp.full((B, 1), S + step, jnp.int32)
        l0, c0, _ = m0.forward(params, {"tokens": nt, "positions": pos,
                                        "cond_memory": mem}, c0)
        # the cached variant decodes WITHOUT the conditioning input at all
        l1, c1, _ = m1.forward(params, {"tokens": nt, "positions": pos}, c1)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=1e-5, atol=1e-5)


def test_moe_dense_decode_matches_dispatch():
    mo = ArchConfig(name="g", arch_type="moe", n_layers=2, d_model=64,
                    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                    moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0))
    md = mo.with_overrides(moe_dense_decode=True)
    m0, m1 = Model(mo, dtype=jnp.float32), Model(md, dtype=jnp.float32)
    params = m0.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 97)
    l0, _, a0 = m0.forward(params, {"tokens": toks})
    l1, _, a1 = m1.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(a0), float(a1), rtol=1e-5)
