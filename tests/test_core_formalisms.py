"""Unit + property tests for the five scaling formalisms and the fitting code."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CoverageParams, coverage, cost_total, energy_total,
                        fit_coverage_joint, fit_power_law, latency,
                        samples_for_coverage, empirical_coverage,
                        simulate_outcomes)
from repro.core.devices import EDGE_CPU, EDGE_GPU_NVIDIA, EDGE_NPU
from repro.core.formalisms import device_task_match, quant_factor


# --------------------------------------------------------------- Formalism 1
@given(S=st.floats(1, 1e4), N=st.floats(1, 1e5), T=st.floats(1, 1e5))
@settings(max_examples=200, deadline=None)
def test_coverage_bounds(S, N, T):
    c = coverage(S, N, T)
    assert 0.0 <= c <= 1.0


@given(S=st.floats(1, 1e3), N=st.floats(10, 1e4), T=st.floats(8, 2048),
       dS=st.floats(1.01, 10))
@settings(max_examples=200, deadline=None)
def test_coverage_monotone_in_samples(S, N, T, dS):
    assert coverage(S * dS, N, T) >= coverage(S, N, T) - 1e-12


def test_coverage_inverse_roundtrip():
    p = CoverageParams.calibrated(124.0)
    for target in (0.3, 0.5, 0.7, 0.9):
        S = samples_for_coverage(target, 124.0, 256.0, p)
        assert math.isclose(coverage(S, 124.0, 256.0, p), target,
                            rel_tol=1e-9)


def test_calibrated_hits_paper_table16():
    """C(20, N, 256) == 0.70 after per-model calibration, all five models."""
    for n_m in (124, 350, 500, 1236, 2600):
        p = CoverageParams.calibrated(float(n_m), target_cov=0.70)
        assert math.isclose(coverage(20, n_m, 256, p), 0.70, rel_tol=1e-9)


# --------------------------------------------------------------- fitting
def test_fit_recovers_exponent_exactly():
    p = CoverageParams.calibrated(124.0)
    S = [1, 2, 5, 10, 15, 20]
    C = [coverage(s, 124.0, 256.0, p) for s in S]
    fit = fit_power_law(S, C)
    assert abs(fit.beta - 0.7) < 1e-6
    assert fit.r2 > 0.9999


def test_joint_fit_recovers_both_exponents():
    p = CoverageParams(alpha=2e-4, beta_N=0.65, beta_S=0.75)
    N, S, C = [], [], []
    for n in (125, 350, 500, 1200, 2600):
        for s in (1, 2, 5, 10, 20):
            N.append(n); S.append(s)
            C.append(coverage(s, n, 256.0, p))
    fit = fit_coverage_joint(N, S, C)
    assert abs(fit.beta_N - 0.65) < 1e-6
    assert abs(fit.beta_S - 0.75) < 1e-6


def test_simulated_outcomes_have_paper_beta():
    out = simulate_outcomes(n_tasks=2000, n_samples=50, target_cov=0.70,
                            seed=3)
    ks = [1, 2, 5, 10, 15, 20]
    cov = empirical_coverage(out, ks)
    fit = fit_power_law(ks, [cov[k] for k in ks], n_bootstrap=200)
    assert 0.60 <= fit.beta <= 0.82, fit.beta        # paper band is [0.64,0.76]
    assert abs(cov[20] - 0.70) < 0.06


def test_empirical_coverage_unbiased_estimator():
    # all successes -> pass@k = 1; none -> 0
    assert empirical_coverage(np.ones((5, 10), bool), [1, 5])[5] == 1.0
    assert empirical_coverage(np.zeros((5, 10), bool), [1, 5])[5] == 0.0
    # exactly one success out of 10 samples: pass@1 = 1/10
    out = np.zeros((1000, 10), bool)
    out[:, 0] = True
    cov = empirical_coverage(out, [1])
    assert math.isclose(cov[1], 0.1, rel_tol=1e-9)


# --------------------------------------------------------------- Formalisms 2-5
def test_energy_scaling_shape():
    e1 = energy_total(10, 125, 256, "fp16", EDGE_GPU_NVIDIA)
    e2 = energy_total(20, 125, 256, "fp16", EDGE_GPU_NVIDIA)
    assert math.isclose(e2 / e1, 2.0, rel_tol=1e-9)     # linear in S
    eN = energy_total(10, 250, 256, "fp16", EDGE_GPU_NVIDIA)
    assert math.isclose(eN / e1, 2 ** 0.9, rel_tol=1e-9)  # sublinear in N
    ef8 = energy_total(10, 125, 256, "fp8", EDGE_GPU_NVIDIA)
    assert math.isclose(ef8 / e1, 0.65, rel_tol=1e-9)


def test_latency_decomposition():
    lb = latency(S=20, T=256, N=125e6, device=EDGE_GPU_NVIDIA,
                 heterogeneous=True)
    assert lb.prefill_s > 0 and lb.decode_s > 0 and lb.overhead_s > 0
    assert lb.total_s == pytest.approx(
        lb.prefill_s + lb.decode_s + lb.io_s + lb.overhead_s)
    # decode dominated by bandwidth disadvantage on CPU
    lb_cpu = latency(S=20, T=256, N=125e6, device=EDGE_CPU)
    assert lb_cpu.decode_s > lb.decode_s


def test_cost_components_positive():
    c = cost_total(20, 1000.0, EDGE_GPU_NVIDIA)
    assert c["total"] == pytest.approx(
        c["amortization"] + c["energy"] + c["maintenance"])
    assert all(v >= 0 for v in c.values())


def test_device_task_match_roofline():
    # decode-like intensity ~1 is memory-bound everywhere
    assert device_task_match(1.0, EDGE_GPU_NVIDIA) == "memory-bound"
    # prefill-like intensity is compute-bound on the GPU (ridge ~133)
    assert device_task_match(1000.0, EDGE_GPU_NVIDIA) == "compute-bound"
    # NPU ridge = 13e12/50e9 = 260
    assert device_task_match(200.0, EDGE_NPU) == "memory-bound"


def test_quant_factor_table():
    assert quant_factor("fp16") == 1.0
    assert quant_factor("fp8") == 0.65
