"""Scheduler-centric serving: admission -> mixed-tier batching -> backend.

Covers the PR 4 refactor contract:
* parity — a single-tier, single-request stream through the scheduler is
  token-identical to `ServingEngine.generate` (the pre-refactor monolith's
  behaviour, which the engine now reproduces over the backend step API);
* scheduler invariants (deterministic + hypothesis-gated): FIFO within a
  tier (per static-shape bucket; no starvation), batches never mix
  prompt-length buckets, and the simulated per-tier service latency never
  exceeds the tier cap when the frontier admits a feasible point at some
  batch size;
* batch-aware routing: merged caps, batch-workload re-costing (weight-
  streaming amortization), per-tier energy attribution;
* control-loop wiring: drift re-anneals land at the next batch boundary;
* telemetry: "serve" trace records with SignalSet snapshots.

Policy tests run against stub backends/routers (no JAX in the loop) so the
hypothesis passes are cheap; integration tests use the real tiny model and
a real PGSAM frontier.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import Constraints, Workload
from repro.core.devices import EDGE_PLATFORM
from repro.models import ArchConfig
from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                         SLATier, default_tiers, merge_tiers)
from repro.serving import (ContinuousBatchingScheduler, RequestQueue,
                           SchedulerConfig)

CFG = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
W = Workload(batch=1, prompt_tokens=8, decode_tokens=6, samples=2)
UNCONSTRAINED = Constraints(latency_budget_factor=None)


# ------------------------------------------------------------------- stubs

class _StubHandle:
    def __init__(self, prompts, repeats, max_new):
        self.prompts = prompts
        self.repeats = repeats
        self.plen = len(prompts[0])
        self.steps_left = max_new - 1

    @property
    def n_sequences(self):
        return sum(self.repeats)

    @property
    def done(self):
        return self.steps_left <= 0


class _StubBackend:
    """Scheduling-policy double: records batches, never touches JAX."""

    def __init__(self, max_slots=None):
        self.max_slots = max_slots
        self.slots_in_use = 0
        self.batches = []              # (plens, repeats) per formed batch
        self.placements = []

    @property
    def slots_free(self):
        if self.max_slots is None:
            return None
        return self.max_slots - self.slots_in_use

    def note_placement(self, placement):
        self.placements.append(placement)

    def start_batch(self, prompts, n_samples, max_new, temperature, rng,
                    extras=None):
        plens = [len(p) for p in prompts]
        assert len(set(plens)) == 1, "backend got a mixed-bucket batch"
        h = _StubHandle(list(prompts), list(n_samples), max_new)
        self.slots_in_use += h.n_sequences
        self.batches.append((plens, list(n_samples)))
        return h

    def decode_step(self, h):
        h.steps_left -= 1
        return not h.done

    def finalize(self, h):
        self.slots_in_use -= h.n_sequences
        return [SimpleNamespace(prompt=p, samples=[], logprobs=[])
                for p in h.prompts]


class _StubRouter:
    """Fixed-latency routing double (no frontier, no anneal)."""

    def __init__(self, tiers, base_latency_s=1.0, per_request_s=0.25):
        self.tiers = {t.name: t for t in tiers}
        self.base = base_latency_s
        self.per_request = per_request_s

    def resolve_tier(self, tier):
        return self.tiers[tier] if isinstance(tier, str) else tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, **kw):
        members = [self.resolve_tier(t) for t in tiers]
        latency = self.base + self.per_request * len(members)
        return SimpleNamespace(
            tier=merge_tiers(members), tier_counts={},
            assignment=object(), point_index=0, meets_caps=True,
            batch_costs=None, energy_j=1.0 * len(members),
            latency_s=latency, notes=[])


def _tiers3():
    return [SLATier("interactive", energy_weight=0.0, latency_weight=1.0),
            SLATier("standard", energy_weight=0.5, latency_weight=0.5),
            SLATier("economy", energy_weight=1.0, latency_weight=0.0)]


def _prompt(n):
    return np.arange(1, n + 1, dtype=np.int32)


def _run_stream(tier_names, plens, max_batch=4, max_slots=None,
                n_samples=1):
    """Submit one request per (tier, plen) pair and drain; returns the
    scheduler (stub backend + stub router)."""
    backend = _StubBackend(max_slots=max_slots)
    sched = ContinuousBatchingScheduler(
        backend, _StubRouter(_tiers3()),
        SchedulerConfig(max_batch_requests=max_batch, max_new_tokens=4))
    for tier, plen in zip(tier_names, plens):
        adm = sched.submit(_prompt(plen), tier=tier, n_samples=n_samples)
        assert adm.admitted
    sched.run_until_idle()
    return sched


# ------------------------------------------------------- policy invariants

def _check_fifo_within_tier_and_bucket(sched):
    """Completion order within a (tier, bucket) class follows admission
    order, and every admitted request completed (no starvation)."""
    n_submitted = sched.queue._next_id
    assert len(sched.completed) == n_submitted
    order = {}
    for c in sorted(sched.completed.values(),
                    key=lambda c: (c.batch_id, c.request.seq)):
        key = (c.request.tier_name, len(c.request.prompt))
        order.setdefault(key, []).append(c.request.seq)
    for key, seqs in order.items():
        assert seqs == sorted(seqs), (key, seqs)


def _check_no_bucket_mixing(sched):
    for plens, _ in sched.backend.batches:
        assert len(set(plens)) == 1, plens
    for rec in sched.records:
        assert rec.n_requests <= sched.config.max_batch_requests


def test_fifo_within_tier_single_bucket():
    tiers = ["interactive", "economy", "interactive", "standard",
             "economy", "interactive", "standard", "economy"]
    sched = _run_stream(tiers, [8] * len(tiers), max_batch=3)
    _check_fifo_within_tier_and_bucket(sched)
    # single bucket -> per-tier FIFO is global FIFO
    done = sorted(sched.completed.values(),
                  key=lambda c: (c.batch_id, c.request.seq))
    assert [c.request.seq for c in done] == list(range(len(tiers)))


def test_fifo_within_tier_mixed_buckets():
    rng = np.random.default_rng(0)
    tiers = [["interactive", "standard", "economy"][i]
             for i in rng.integers(0, 3, 24)]
    plens = [int(p) for p in rng.choice([4, 8, 16], 24)]
    sched = _run_stream(tiers, plens, max_batch=4)
    _check_fifo_within_tier_and_bucket(sched)
    _check_no_bucket_mixing(sched)


def test_batches_never_mix_buckets_or_exceed_slots():
    rng = np.random.default_rng(1)
    plens = [int(p) for p in rng.choice([4, 8], 16)]
    sched = _run_stream(["economy"] * 16, plens, max_batch=8, max_slots=6,
                        n_samples=2)
    _check_no_bucket_mixing(sched)
    for _, repeats in sched.backend.batches:
        assert sum(repeats) <= 6            # KV slot budget respected


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["interactive", "standard",
                                           "economy"]),
                          st.sampled_from([4, 8, 16])),
                min_size=1, max_size=24),
       st.integers(1, 6))
def test_fifo_and_bucket_invariants_property(stream, max_batch):
    sched = _run_stream([t for t, _ in stream], [p for _, p in stream],
                        max_batch=max_batch)
    _check_fifo_within_tier_and_bucket(sched)
    _check_no_bucket_mixing(sched)


# ------------------------------------------------ real-frontier fixtures

@pytest.fixture(scope="module")
def orch():
    return PGSAMOrchestrator(
        EDGE_PLATFORM, UNCONSTRAINED,
        config=PGSAMConfig(seed=0, iters_max=300, incremental=True),
        energy_model="v2")


@pytest.fixture(scope="module")
def router(orch):
    placed = [a for a in orch.pareto_frontier(CFG, W) if a.mapping]
    base = min(a.latency_s for a in placed) / 0.9
    return ParetoRouter(orch, CFG, W, tiers=default_tiers(base))


# --------------------------------------------------------- batch routing

def test_route_batch_single_tier_keeps_name_and_attributes_all_energy(
        router):
    d = router.route_batch(["economy", "economy", "economy"])
    assert d.tier.name == "economy"
    assert d.tier_counts == {"economy": 3}
    assert d.workload.batch == 3
    assert d.per_tier_energy_j["economy"] == pytest.approx(d.energy_j)


def test_route_batch_merges_caps_and_splits_energy(router):
    d = router.route_batch(["interactive", "economy", "economy"])
    assert d.tier.name == "economy+interactive"
    # merged cap is the tightest member cap (economy has none)
    assert d.tier.latency_p99_s == \
        pytest.approx(router.tiers["interactive"].latency_p99_s)
    assert sum(d.per_tier_energy_j.values()) == pytest.approx(d.energy_j)
    assert d.per_tier_energy_j["economy"] == \
        pytest.approx(2 * d.per_tier_energy_j["interactive"])


def test_batching_amortizes_weight_streaming(router):
    """The physical lever: decode re-streams weights once per token
    regardless of batch size, so a batch of 8 costs far less than 8x a
    batch of 1 in both time and energy."""
    a = router.frontier[0]
    c1 = router.recost(a, router.batch_workload(1))
    c8 = router.recost(a, router.batch_workload(8))
    assert c8.makespan_s < 8 * c1.makespan_s
    assert c8.energy_j < 8 * c1.energy_j
    # and the canonical-workload costing is reproduced exactly
    c_canon = router.recost(a, router.workload)
    assert c_canon.energy_j == pytest.approx(a.energy_j)
    assert c_canon.makespan_s == pytest.approx(a.latency_s)


def _feasible_exists(router, tier_names, n):
    merged = merge_tiers([router.resolve_tier(t) for t in tier_names])
    w = router.batch_workload(n)
    for a in router.frontier:
        c = router.recost(a, w)
        ok = True
        if merged.latency_p99_s is not None and \
                c.makespan_s > merged.latency_p99_s * (1 + 1e-9):
            ok = False
        if merged.energy_cap_w is not None and \
                c.energy_j / max(c.makespan_s, 1e-12) > \
                merged.energy_cap_w * (1 + 1e-9):
            ok = False
        if ok:
            return True
    return False


def _check_caps_respected(router, tier_names):
    d = router.route_batch(tier_names)
    if _feasible_exists(router, tier_names, len(tier_names)):
        assert d.meets_caps
        if d.tier.latency_p99_s is not None:
            assert d.latency_s <= d.tier.latency_p99_s * (1 + 1e-9)
        if d.tier.energy_cap_w is not None:
            assert d.avg_power_w <= d.tier.energy_cap_w * (1 + 1e-9)
    else:
        assert not d.meets_caps


def test_route_batch_caps_respected_deterministic(router):
    rng = np.random.default_rng(2)
    names = ["interactive", "standard", "economy"]
    for _ in range(20):
        n = int(rng.integers(1, 9))
        _check_caps_respected(router,
                              [names[i] for i in rng.integers(0, 3, n)])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(["interactive", "standard", "economy"]),
                min_size=1, max_size=10))
def test_route_batch_caps_respected_property(router, tier_names):
    _check_caps_respected(router, tier_names)


def test_scheduler_shrinks_batch_to_meet_tight_cap(router, orch):
    """A tight-SLA member caps how much batching its batch can absorb: the
    scheduler sheds requests until the merged cap is satisfiable whenever
    the frontier admits a feasible point at SOME batch size."""
    # tightest cap that is feasible at batch size 1 but not at size 8
    c1 = min(router.recost(a, router.batch_workload(1)).makespan_s
             for a in router.frontier)
    c8 = min(router.recost(a, router.batch_workload(8)).makespan_s
             for a in router.frontier)
    assert c8 > c1  # sanity: batching stretches the makespan
    tight = SLATier("tight", latency_p99_s=(c1 + c8) / 2,
                    energy_weight=0.0, latency_weight=1.0)
    router.add_tier(tight)
    try:
        backend = _StubBackend()
        sched = ContinuousBatchingScheduler(
            backend, router, SchedulerConfig(max_batch_requests=8))
        for _ in range(8):
            # workload-aligned requests so the cap's basis (batch_workload)
            # matches what the scheduler prices the batch at
            sched.submit(_prompt(W.prompt_tokens), tier="tight",
                         n_samples=W.samples,
                         max_new_tokens=W.decode_tokens)
        sched.run_until_idle()
        assert len(sched.records) > 1          # forced to split
        for rec in sched.records:
            assert rec.meets_caps
            assert rec.latency_s <= tight.latency_p99_s * (1 + 1e-9)
    finally:
        router.tiers.pop("tight", None)


# ----------------------------------------------------- admission control

def test_admission_rejects_unknown_tier_and_bounds_depth(router):
    q = RequestQueue(router, max_queue_depth=2)
    bad = q.submit(_prompt(4), "no-such-tier")
    assert not bad.admitted and "unknown tier" in bad.reason
    assert q.submit(_prompt(4), "economy").admitted
    assert q.submit(_prompt(4), "economy").admitted
    full = q.submit(_prompt(4), "economy")
    assert not full.admitted and "queue full" in full.reason
    assert q.submit(_prompt(4), "standard").admitted   # per-tier bound
    assert len(q.rejections) == 2


def test_admission_raises_samples_to_coverage_floor(router):
    floor_tier = SLATier("quality", min_quality=0.95, energy_weight=1.0)
    need = router.required_samples(floor_tier)
    assert need is not None and need > W.samples
    q = RequestQueue(router)
    adm = q.submit(_prompt(4), floor_tier, n_samples=1)
    assert adm.admitted and adm.raised_samples == need
    [req] = q.pop_batch(1)
    assert req.n_samples == need


def test_extras_incompatible_requests_split_batches():
    """One batch stacks one set of per-request extras rows: a request with
    different (or no) extras keys starts its own batch, FIFO preserved."""
    backend = _StubBackend()
    sched = ContinuousBatchingScheduler(
        backend, _StubRouter(_tiers3()),
        SchedulerConfig(max_batch_requests=8, max_new_tokens=4))
    row = {"bias": np.zeros(3, np.float32)}
    for extras in (row, row, None, row):
        assert sched.submit(_prompt(8), tier="economy",
                            extras=extras).admitted
    sched.run_until_idle()
    _check_fifo_within_tier_and_bucket(sched)
    assert [r.n_requests for r in sched.records] == [2, 1, 1]


def test_oversized_request_rejected_not_crashed():
    """A request whose sampling budget can never fit the KV slot budget is
    rejected at admission instead of crashing the serving loop (and the
    loop keeps making progress for everyone else)."""
    backend = _StubBackend(max_slots=4)
    sched = ContinuousBatchingScheduler(
        backend, _StubRouter(_tiers3()),
        SchedulerConfig(max_batch_requests=8, max_new_tokens=4))
    bad = sched.submit(_prompt(8), tier="economy", n_samples=5)
    assert not bad.admitted and "exceeds the KV budget" in bad.reason
    ok = sched.submit(_prompt(8), tier="economy", n_samples=4)
    assert ok.admitted
    sched.run_until_idle()
    assert ok.request_id in sched.completed


def test_caller_rng_varies_multi_request_batches():
    """Two identical multi-request streams differing only in the caller's
    rng must produce different samples (the pre-refactor generate
    contract); the same rng reproduces bit-identically."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.models import Model
    from repro.serving import ExecutionBackend

    model = Model(CFG, dtype=jnp.float32)
    params = model.init(jax.random.key(0))

    def run(seed):
        backend = ExecutionBackend(model, params)
        sched = ContinuousBatchingScheduler(
            backend, _StubRouter(_tiers3()),
            SchedulerConfig(max_batch_requests=4))
        ids = [sched.submit(_prompt(4), tier="economy", n_samples=1,
                            max_new_tokens=4,
                            rng=jax.random.key(seed)).request_id
               for _ in range(3)]
        done = sched.run_until_idle()
        assert done[ids[0]].batch_id == done[ids[2]].batch_id  # one batch
        return np.concatenate([done[i].result.samples[0] for i in ids])

    a, b, c = run(1), run(1), run(2)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


# ------------------------------------------------- control-loop boundary

def test_drift_reanneal_lands_at_next_batch_boundary(router, orch):
    from repro.core import SafetyMonitor
    from repro.qeil2 import ControlLoop, LoopConfig

    backend = _StubBackend()
    sched = ContinuousBatchingScheduler(
        backend, router, SchedulerConfig(max_batch_requests=4))
    safety = SafetyMonitor(EDGE_PLATFORM)
    orch.safety = safety
    try:
        loop = ControlLoop(orch, safety, CFG, W,
                           LoopConfig(dt_s=1.0, reanneal_iters=150),
                           router=router, scheduler=sched)
        loop.step()                                 # cold start: no boundary
        assert sched.reroute_boundaries == 0
        sched.submit(_prompt(W.prompt_tokens), tier="economy")
        sched.run_until_idle()
        pre = sched.records[-1]
        assert not pre.reroute
        victim = loop.assignment.device_names()[0]
        safety.health.fail_device(victim, now_s=loop.t_s)
        loop.step()                                 # drift -> re-anneal
        assert sched.reroute_boundaries == 1
        sched.submit(_prompt(W.prompt_tokens), tier="economy")
        sched.run_until_idle()
        post = sched.records[-1]
        assert post.reroute                         # boundary marked
        done = sched.completed[max(sched.completed)]
        assert victim not in done.decision.assignment.device_names()
    finally:
        orch.safety = None
        safety.health.recover_device(victim)
        orch.invalidate_frontier()
        router.set_healthy(None)


# ------------------------------------------------------------- telemetry

def test_scheduler_emits_serve_trace_records(router):
    from repro.qeil2 import TraceStore

    trace = TraceStore()
    backend = _StubBackend()
    sched = ContinuousBatchingScheduler(
        backend, router, SchedulerConfig(max_batch_requests=4), trace=trace)
    for tier in ("interactive", "economy", "economy"):
        sched.submit(_prompt(W.prompt_tokens), tier=tier)
    sched.run_until_idle()
    recs = trace.records("serve")
    assert len(recs) == len(sched.records) >= 1
    r = recs[0]
    assert r["tier_mix"] and r["latency_s"] > 0 and r["energy_j"] > 0
    # v2-costed batches carry per-stage SignalSet snapshots -> the same
    # fitter that consumes ControlLoop step records can consume serve ones
    assert r["signals"]
    for snap in r["signals"].values():
        assert {"dasi", "cpq", "phi"} <= set(snap)


def test_trace_serve_schema_rejects_malformed():
    from repro.qeil2 import TraceStore

    with pytest.raises(ValueError):
        TraceStore().ingest({"kind": "serve", "t_s": 0.0})


# ------------------------------------------------------- parity (jax)

def test_parity_scheduler_vs_engine_single_tier_stream(router):
    """Acceptance: a single-tier, single-request stream through the
    scheduler is token-identical (and logprob-identical) to the
    pre-refactor blocking `ServingEngine.generate`, request by request."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.models import Model
    from repro.serving import ExecutionBackend, ServingEngine

    model = Model(CFG, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, max_new_tokens=5)
    backend = ExecutionBackend(model, params)
    sched = ContinuousBatchingScheduler(backend, router, SchedulerConfig())

    for i, seed in enumerate((7, 11, 13)):
        prompt = np.arange(1, 4, dtype=np.int32) + i
        [want] = engine.generate([prompt], n_samples=3, max_new_tokens=5,
                                 rng=jax.random.key(seed))
        adm = sched.submit(prompt, tier="economy", n_samples=3,
                           max_new_tokens=5, temperature=0.8,
                           rng=jax.random.key(seed))
        got = sched.run_until_idle()[adm.request_id].result
        assert len(got.samples) == len(want.samples)
        for a, b in zip(want.samples, got.samples):
            np.testing.assert_array_equal(a, b)
        assert want.logprobs == got.logprobs
        assert (want.prefill_tokens, want.decode_tokens) == \
            (got.prefill_tokens, got.decode_tokens)
