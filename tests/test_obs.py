"""Observability layer (span tracing + metrics + profiling hooks).

Covers the ISSUE 7 contract:
* metrics registry semantics — counters/gauges/histograms with labels,
  Prometheus text exposition (exact output + escaping), JSON snapshots,
  registration conflicts, null no-ops (hypothesis-gated histogram
  invariants with deterministic companions);
* span tracer — parent/child request structure, batch context, TraceStore
  mirroring and round-trip, lifecycle reconstruction;
* TraceStore hardening — non-finite values and unknown kinds rejected,
  mixed-kind save/load round-trip;
* pipeline integration — admission reason codes, queue depth, occupancy /
  queue-delay observation, per-request ``queue_delay_s`` on BatchRecord
  entries (stub backend/router, no JAX in the loop);
* the pinned guarantee: serving output is bit-identical with the full
  observability stack on vs off (real tiny model, scheduler and engine
  paths);
* benchmarks/compare.py regression detection and the profile entry point's
  fitter-compatible kernel records.
"""
import json
import math
import os
import sys

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.obs import (DEFAULT_BUCKETS, LIFECYCLE, MetricsRegistry, NULL_OBS,
                       NullRegistry, NullTracer, Observability, Tracer,
                       lifecycles_complete, make_observability,
                       reconstruct_lifecycles)
from repro.obs.metrics import PeriodicReporter

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# ============================================================ metrics: core

def test_counter_labels_and_negative_rejected():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", labelnames=("tier",))
    c.inc(tier="interactive")
    c.inc(2, tier="economy")
    c.inc(tier="economy")
    assert c.value(tier="interactive") == 1
    assert c.value(tier="economy") == 3
    assert c.value(tier="never") == 0
    with pytest.raises(ValueError):
        c.inc(-1, tier="economy")
    with pytest.raises(ValueError):
        c.inc()                      # missing label


def test_gauge_set_max_tracks_high_water():
    reg = MetricsRegistry()
    g = reg.gauge("blocks", "in use")
    g.set(4)
    g.inc(2)
    g.dec()
    assert g.value() == 5
    hw = reg.gauge("blocks_hw", "high water")
    for v in (3, 9, 5):
        hw.set_max(v)
    assert hw.value() == 9


def test_histogram_deterministic_counts_and_quantile():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 1.5, 3.0, 100.0):
        h.observe(v)
    assert h.bucket_counts() == [1, 2, 1, 1]          # last bin = overflow
    assert h.cumulative_counts() == [1, 3, 4, 5]
    assert h.total() == 5
    assert h.sum_value() == pytest.approx(106.5)
    # median falls in the (1, 2] bucket; overflow quantiles clamp to the
    # largest finite edge
    assert 1.0 <= h.quantile(0.5) <= 2.0
    assert h.quantile(1.0) == 4.0
    assert math.isnan(reg.histogram("empty", "e").quantile(0.5))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=50.0,
                          allow_nan=False), min_size=1, max_size=60),
       st.floats(min_value=0.01, max_value=0.99))
def test_histogram_invariants_hypothesis(values, q):
    h = MetricsRegistry().histogram("h", "h", buckets=(0.1, 1.0, 10.0))
    for v in values:
        h.observe(v)
    cum = h.cumulative_counts()
    assert cum == sorted(cum)                       # monotone
    assert cum[-1] == len(values) == h.total()      # +Inf catches all
    assert h.sum_value() == pytest.approx(sum(values))
    assert 0.0 <= h.quantile(q) <= 10.0             # bounded by finite edges


# ====================================================== metrics: exposition

def test_prometheus_text_exact():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "served requests", labelnames=("tier",))
    c.inc(3, tier="a")
    g = reg.gauge("depth", "queue depth")
    g.set(2.5)
    want = (
        "# HELP depth queue depth\n"
        "# TYPE depth gauge\n"
        "depth 2.5\n"
        "# HELP reqs_total served requests\n"
        "# TYPE reqs_total counter\n"
        'reqs_total{tier="a"} 3\n'
    )
    assert reg.to_prometheus() == want


def test_prometheus_histogram_exposition_and_escaping():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", labelnames=("op",),
                      buckets=(1.0, 2.0))
    h.observe(0.5, op='we"ird\\na\nme')
    h.observe(5.0, op='we"ird\\na\nme')
    text = reg.to_prometheus()
    assert '\\"' in text and "\\\\" in text and "\\n" in text
    assert 'le="+Inf"' in text
    assert "lat_sum" in text and "lat_count" in text
    # cumulative: the +Inf bucket equals _count
    inf_line = [l for l in text.splitlines() if 'le="+Inf"' in l][0]
    count_line = [l for l in text.splitlines()
                  if l.startswith("lat_count")][0]
    assert inf_line.rsplit(" ", 1)[1] == count_line.rsplit(" ", 1)[1] == "2"


def test_registry_conflicts_and_reuse():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", "x")
    assert reg.counter("x_total", "x") is c1        # get-or-create
    with pytest.raises(ValueError):
        reg.gauge("x_total", "x")                   # type conflict
    with pytest.raises(ValueError):
        reg.counter("x_total", "x", labelnames=("t",))   # label conflict
    assert "x_total" in reg.names()


def test_null_registry_is_inert():
    reg = NullRegistry()
    assert not reg.enabled
    c = reg.counter("a_total", "a", labelnames=("t",))
    c.inc(tier_whatever="v")                        # labels unchecked
    g = reg.gauge("g", "g")
    g.set(3)
    g.set_max(9)
    h = reg.histogram("h", "h")
    h.observe(1.0)
    with pytest.raises(RuntimeError):
        reg.write("/tmp/nope.json")


def test_registry_write_and_periodic_reporter(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n_total", "n").inc(7)
    path = str(tmp_path / "m.json")
    reporter = PeriodicReporter(reg, path, interval_s=10.0)
    assert reporter.maybe_write(0.0)                # first call writes
    assert not reporter.maybe_write(5.0)            # within interval
    assert reporter.maybe_write(11.0)
    snap = json.load(open(path))
    assert snap["n_total"]["values"][0]["value"] == 7
    prom = open(str(tmp_path / "m.prom")).read()
    assert "n_total 7\n" in prom


# ================================================================== tracer

def test_tracer_parenting_and_batch_context():
    tr = Tracer()
    root = tr.emit("admit", 0.0, request_id=5, tier="standard")
    tr.batch_context = 3
    child = tr.emit("queue", 0.0, 1.0, request_id=5)
    recs = tr.records()
    assert len(tr) == 2
    assert recs[0]["kind"] == "span" and recs[0]["name"] == "admit"
    assert recs[1]["parent_id"] == root
    assert recs[1]["batch_id"] == 3                 # from batch_context
    assert child != root


def test_tracer_mirrors_into_store_and_roundtrips(tmp_path):
    from repro.qeil2 import TraceStore
    store = TraceStore()
    tr = Tracer(store=store)
    tr.emit("admit", 0.0, request_id=0)
    tr.emit("release", 0.0, 2.0, request_id=0, clock="sim")
    assert len(store.records("span")) == 2
    p = str(tmp_path / "t.jsonl")
    store.save(p)
    back = TraceStore(path=p)
    assert [r["name"] for r in back.records("span")] == ["admit", "release"]


def test_null_tracer_is_inert():
    tr = NullTracer()
    assert not tr.enabled
    assert tr.emit("admit", 0.0) == -1
    assert len(tr) == 0
    with pytest.raises(RuntimeError):
        tr.save("/tmp/nope.jsonl")


def test_lifecycle_reconstruction_complete_and_incomplete():
    tr = Tracer()
    tr.emit("admit", 0.0, request_id=0, admitted=True)
    tr.batch_context = 0
    tr.emit("schedule", 1.0, 2.0)
    tr.emit("prefill", 0.0, 0.1, clock="wall")
    tr.emit("decode", 0.1, 0.2, clock="wall", step=0)
    tr.emit("queue", 0.0, 1.0, request_id=0)
    tr.emit("release", 2.0, 2.0, request_id=0, latency_s=2.0)
    life = reconstruct_lifecycles(tr.spans)
    assert life[0]["complete"] and life[0]["missing"] == []
    assert lifecycles_complete(tr.spans, expect_requests=1)
    # a second request that never releases is incomplete
    tr.emit("admit", 3.0, request_id=1, admitted=True)
    tr.emit("queue", 3.0, 4.0, request_id=1)
    life = reconstruct_lifecycles(tr.spans)
    assert not life[1]["complete"] and "release" in life[1]["missing"]
    assert not lifecycles_complete(tr.spans, expect_requests=2)


# ==================================================== TraceStore hardening

def test_tracestore_rejects_nonfinite_and_unknown_kind():
    from repro.qeil2 import TraceStore
    store = TraceStore()
    with pytest.raises(ValueError, match="non-finite"):
        store.ingest({"kind": "span", "name": "x", "t0_s": float("nan"),
                      "t1_s": 1.0})
    with pytest.raises(ValueError, match="non-finite"):
        store.ingest({"kind": "span", "name": "x", "t0_s": 0.0, "t1_s": 1.0,
                      "attrs": {"deep": [1.0, float("inf")]}})
    with pytest.raises(ValueError, match="unknown trace record kind"):
        store.ingest({"kind": "mystery", "name": "x"})
    assert len(store) == 0                          # nothing leaked in


def test_tracestore_mixed_kind_roundtrip(tmp_path):
    from repro.qeil2 import TraceStore
    store = TraceStore()
    store.ingest({"kind": "kernel", "kernel": "flash_attention", "flops": 1.0,
                  "bytes": 2.0, "measured_us": 3.0, "roofline_us": 0.5,
                  "quant": "int8"})
    store.ingest({"kind": "span", "name": "admit", "t0_s": 0.0, "t1_s": 0.0,
                  "request_id": 0})
    p = str(tmp_path / "mixed.jsonl")
    store.save(p)
    back = TraceStore(path=p)
    assert len(back.records("kernel")) == 1
    assert len(back.records("span")) == 1
    assert back.records("kernel")[0]["quant"] == "int8"


# ================================================ pipeline (stub) integration

from types import SimpleNamespace

from repro.qeil2 import SLATier, merge_tiers
from repro.serving import (ContinuousBatchingScheduler, RequestQueue,
                           SchedulerConfig)


class _StubHandle:
    def __init__(self, prompts, repeats, max_new):
        self.prompts = prompts
        self.repeats = repeats
        self.plen = len(prompts[0])
        self.steps_left = max_new - 1

    @property
    def n_sequences(self):
        return sum(self.repeats)

    @property
    def done(self):
        return self.steps_left <= 0


class _StubBackend:
    def __init__(self):
        self.slots_in_use = 0

    slots_free = None

    def note_placement(self, placement):
        pass

    def start_batch(self, prompts, n_samples, max_new, temperature, rng,
                    extras=None):
        h = _StubHandle(list(prompts), list(n_samples), max_new)
        self.slots_in_use += h.n_sequences
        return h

    def decode_step(self, h):
        h.steps_left -= 1
        return not h.done

    def finalize(self, h):
        self.slots_in_use -= h.n_sequences
        return [SimpleNamespace(prompt=p, samples=[], logprobs=[])
                for p in h.prompts]


class _StubRouter:
    def __init__(self, tiers):
        self.tiers = {t.name: t for t in tiers}

    def resolve_tier(self, tier):
        return self.tiers[tier] if isinstance(tier, str) else tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, **kw):
        members = [self.resolve_tier(t) for t in tiers]
        return SimpleNamespace(
            tier=merge_tiers(members), tier_counts={},
            assignment=object(), point_index=0, meets_caps=True,
            batch_costs=None, energy_j=1.0 * len(members),
            latency_s=0.5, notes=[],
            per_tier_energy_j={members[0].name: 1.0 * len(members)})


def _tiers3():
    return [SLATier("interactive", energy_weight=0.0, latency_weight=1.0),
            SLATier("standard", energy_weight=0.5, latency_weight=0.5),
            SLATier("economy", energy_weight=1.0, latency_weight=0.0)]


def test_scheduler_metrics_spans_and_queue_delay_entries():
    obs = make_observability()
    sched = ContinuousBatchingScheduler(
        _StubBackend(), _StubRouter(_tiers3()),
        SchedulerConfig(max_batch_requests=4, max_new_tokens=3), obs=obs)
    sched.advance_to(1.0)          # arrivals in the past: positive delays
    for i in range(3):
        adm = sched.submit(np.arange(1, 5, dtype=np.int32), tier="economy",
                           n_samples=1, arrival_s=0.1 * i)
        assert adm.admitted
    sched.on_reorchestrate()
    sched.run_until_idle()

    reg = obs.metrics
    adm_c = reg.get("serving_admission_total")
    assert adm_c.value(outcome="admitted", reason="ok") == 3
    assert reg.get("serving_queue_depth").value(tier="economy") == 0
    occ = reg.get("serving_batch_occupancy")
    assert occ.total() == 1 and occ.sum_value() == 3    # one 3-request batch
    assert reg.get("serving_queue_delay_s").total(tier="economy") == 3
    assert reg.get("serving_energy_j_total").value(tier="economy") == 3.0
    assert reg.get("serving_requests_completed_total").value(
        tier="economy") == 3
    assert reg.get("serving_reanneal_boundaries_total").value() == 1

    # per-request queue delay rides on the batch record (satellite c)
    [rec] = list(sched.records)
    assert len(rec.request_entries) == 3
    for e in rec.request_entries:
        assert e["queue_delay_s"] >= 0.0 and e["tier"] == "economy"

    # stub backends emit no prefill/decode wall spans; the scheduler-side
    # lifecycle (admit -> queue -> schedule -> release) must still be there
    names = {s.name for s in obs.tracer.spans}
    assert {"admit", "queue", "schedule", "release"} <= names
    per_req = {s.request_id for s in obs.tracer.spans if s.name == "release"}
    assert per_req == {0, 1, 2}


def test_admission_reject_reason_codes():
    obs = make_observability()
    q = RequestQueue(router=_StubRouter(_tiers3()), max_queue_depth=1,
                     obs=obs)
    p = np.arange(1, 4, dtype=np.int32)
    assert q.submit(p, tier="nope").reason_code == "unknown_tier"
    assert q.submit(p, tier="economy").admitted
    assert q.submit(p, tier="economy").reason_code == "queue_full"
    assert q.submit(p, tier="standard", n_samples=4,
                    budget=2).reason_code == "kv_budget"
    c = obs.metrics.get("serving_admission_total")
    assert c.value(outcome="rejected", reason="unknown_tier") == 1
    assert c.value(outcome="rejected", reason="queue_full") == 1
    assert c.value(outcome="rejected", reason="kv_budget") == 1
    assert c.value(outcome="admitted", reason="ok") == 1
    rejected = [s for s in obs.tracer.spans
                if s.name == "admit" and not s.attrs.get("admitted")]
    assert [s.attrs["reason"] for s in rejected] == \
        ["unknown_tier", "queue_full", "kv_budget"]


def test_serve_trace_records_carry_request_entries():
    from repro.qeil2 import TraceStore
    trace = TraceStore()
    sched = ContinuousBatchingScheduler(
        _StubBackend(), _StubRouter(_tiers3()),
        SchedulerConfig(max_batch_requests=4, max_new_tokens=3), trace=trace)
    sched.submit(np.arange(1, 5, dtype=np.int32), tier="standard")
    sched.run_until_idle()
    [rec] = trace.records("serve")
    assert rec["requests"][0]["tier"] == "standard"
    assert rec["requests"][0]["queue_delay_s"] >= 0.0


# ============================================== pinned: bit-parity obs on/off

CFG_KW = dict(name="t-obs", arch_type="dense", n_layers=2, d_model=64,
              n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


def _run_real_stream(obs):
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model
    from repro.serving import ExecutionBackend

    model = Model(ArchConfig(**CFG_KW), dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    backend = ExecutionBackend(model, params, obs=obs)
    sched = ContinuousBatchingScheduler(
        backend, _StubRouter(_tiers3()),
        SchedulerConfig(max_batch_requests=4, max_new_tokens=4, seed=3),
        obs=obs)
    ids = []
    for i in range(3):
        adm = sched.submit(np.arange(1, 4, dtype=np.int32) + i,
                           tier="economy", n_samples=2, temperature=0.8)
        ids.append(adm.request_id)
    done = sched.run_until_idle()
    return [(done[i].result.samples, done[i].result.logprobs) for i in ids]


def test_pinned_bit_parity_scheduler_obs_on_off():
    """The observability stack must be a pure observer: identical sampled
    tokens and logprobs with the full stack on vs off."""
    pytest.importorskip("jax")
    off = _run_real_stream(None)
    on_obs = make_observability()
    on = _run_real_stream(on_obs)
    assert len(on_obs.tracer) > 0                    # actually instrumented
    assert on_obs.metrics.get(
        "serving_tokens_out_total").value() > 0
    for (sa, la), (sb, lb) in zip(off, on):
        assert la == lb
        for a, b in zip(sa, sb):
            np.testing.assert_array_equal(a, b)


def test_pinned_bit_parity_engine_obs_on_off():
    pytest.importorskip("jax")
    import jax
    import jax.numpy as jnp
    from repro.models import ArchConfig, Model
    from repro.serving import ServingEngine

    model = Model(ArchConfig(**CFG_KW), dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    prompt = np.arange(1, 6, dtype=np.int32)
    outs = []
    for obs in (None, make_observability()):
        engine = ServingEngine(model, params, max_new_tokens=4, obs=obs)
        [r] = engine.generate([prompt], n_samples=2,
                              rng=jax.random.key(11))
        outs.append((r.samples, r.logprobs))
    (sa, la), (sb, lb) = outs
    assert la == lb
    for a, b in zip(sa, sb):
        np.testing.assert_array_equal(a, b)


# ================================================== compare.py + profile.py

def test_bench_compare_identity_and_regression():
    import benchmarks.compare as bc
    base = bc.run(verbose=False)
    assert base["self_check_ok"]
    art = {"acceptance_all": True, "throughput_ratio": 2.0,
           "scheduler": {"completed": 5}}
    assert bc.compare(art, dict(art), "serving_schedule") == []
    worse = {"acceptance_all": True, "throughput_ratio": 1.0,
             "scheduler": {"completed": 5}}
    [f] = bc.compare(art, worse, "serving_schedule")
    assert f["path"] == "throughput_ratio"


def test_profile_records_feed_the_fitter():
    pytest.importorskip("jax")
    from repro.launch.profile import run as profile_run
    from repro.qeil2.telemetry.fit import _eta_key

    res = profile_run(verbose=False, reps=1, kernels=["dequant_matmul"])
    assert res["n_records"] == 2                     # int8 + int4, 1 rep each
    keys = sorted(_eta_key(r) for r in res["records"])
    assert keys == ["dequant_matmul:int4", "dequant_matmul:int8"]
    for r in res["records"]:
        assert r["kind"] == "kernel"
        assert r["flops"] > 0 and r["bytes"] > 0
        assert r["measured_us"] > 0 and r["roofline_us"] > 0


def test_cascade_metrics_and_verify_spans():
    from repro.core.sampling import VerifierCascade

    obs = make_observability()
    casc = VerifierCascade(exact_verify=lambda s: bool(s[-1] % 2),
                           early_stop=True, obs=obs)
    samples = [np.array([1, 2, 3]), np.array([1, 2, 5]), np.array([2, 2, 2])]
    casc.verify(samples, [-1.0, -0.5, -2.0], request_id=7)
    reg = obs.metrics
    assert reg.get("cascade_candidates_total").value() == 3
    assert reg.get("cascade_exact_passed_total").value() >= 1
    spans = [s for s in obs.tracer.spans if s.name == "verify"]
    assert spans and all(s.request_id == 7 for s in spans)
