"""Quantized serving subsystem (PR 6 tentpole).

* pack/unpack int4 round-trips (deterministic + hypothesis property);
* fused dequant-matmul Pallas kernels (interpret mode) match the jnp oracle
  bit-tolerance-tight on non-aligned shapes, int8 and group-wise int4;
* `quantize_model` quantizes every dense except the keep-list, and serving
  through the quantized tree is *bit-identical* to serving the dequantized
  tree (the oracle's dequantize-then-matmul contract), including the exact
  identity case (integer weights at full scale -> zero quantization error);
* perplexity smoke bound: fixed-batch NLL drifts by less than the floor;
* int8 paged KV: per-slot scales quantize on fill and dequantize on read,
  `copy_cache_blocks` moves the scales with the blocks, and the byte
  accounting roughly doubles the block budget at equal bytes;
* `quant_factor` raises ValueError naming the supported formats (was a bare
  KeyError);
* the calibration fitter keys quantized kernel records per-format
  ("dequant_matmul:int8") while full-precision records keep the bare name.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.decomposition import Workload, decompose  # noqa: E402
from repro.core.formalisms import quant_factor  # noqa: E402
from repro.kernels.dequant_matmul import (  # noqa: E402
    dequant_matmul, dequant_matmul_int4_pallas, dequant_matmul_int4_ref,
    dequant_matmul_int8_pallas, dequant_matmul_int8_ref, dequantize_int4,
    dequantize_int8, unpack_int4)
from repro.models import ArchConfig, Model  # noqa: E402
from repro.models.cache import (kv_bytes_per_token, make_cache,  # noqa: E402
                                PagedLayout, copy_cache_blocks)
from repro.quant import (bytes_per_param_for, dequantize_model,  # noqa: E402
                         group_size_for, pack_int4, param_bytes,
                         params_quant_format, quant_workload, quantize_int4,
                         quantize_int8, quantize_model)

CFG = ArchConfig(name="tq", arch_type="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


@pytest.fixture(scope="module")
def model_params():
    model = Model(CFG, dtype=jnp.float32)
    return model, model.init(jax.random.key(0))


# ===================================================== satellite: quant_factor

def test_quant_factor_unknown_format_raises_valueerror():
    with pytest.raises(ValueError, match="int4"):
        quant_factor("int3")
    with pytest.raises(ValueError, match="supported"):
        quant_factor("q5_k_m")
    assert quant_factor("int4") == 0.45
    assert quant_factor("INT8") == 0.65


def test_bytes_per_param_for_unknown_raises():
    with pytest.raises(ValueError, match="supported"):
        bytes_per_param_for("int2")
    assert bytes_per_param_for("int4") == 0.5


# ================================================================ pack/unpack

def test_pack_unpack_round_trip_deterministic():
    rng = np.random.default_rng(0)
    q = rng.integers(-8, 8, size=(10, 7)).astype(np.int8)
    packed = pack_int4(jnp.asarray(q))
    assert packed.shape == (5, 7) and packed.dtype == jnp.uint8
    assert np.array_equal(np.asarray(unpack_int4(packed)), q)


def test_pack_unpack_round_trip_stacked_leading_axis():
    rng = np.random.default_rng(1)
    q = rng.integers(-8, 8, size=(3, 6, 5)).astype(np.int8)
    assert np.array_equal(np.asarray(unpack_int4(pack_int4(jnp.asarray(q)))),
                          q)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 12), st.integers(1, 9), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_round_trip_property(half_k, n, seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(2 * half_k, n)).astype(np.int8)
    assert np.array_equal(np.asarray(unpack_int4(pack_int4(jnp.asarray(q)))),
                          q)


def test_group_size_adjusts_to_even_divisor():
    assert group_size_for(64, 32) == 32
    assert group_size_for(48, 32) == 24
    assert group_size_for(10, 32) == 10
    assert group_size_for(6, 4) == 2
    with pytest.raises(ValueError, match="even"):
        group_size_for(7, 4)


# ===================================================== kernel vs oracle parity

@pytest.mark.parametrize("M,K,N", [(5, 48, 19), (1, 32, 130), (9, 64, 64),
                                   (17, 96, 33)])
def test_int8_kernel_matches_oracle_nonaligned(M, K, N):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    qw, scale = quantize_int8(jnp.asarray(rng.normal(size=(K, N)),
                                          jnp.float32))
    want = dequant_matmul_int8_ref(x, qw, scale)
    got = dequant_matmul_int8_pallas(x, qw, scale, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("M,K,N,gs", [(5, 48, 19, 16), (1, 32, 130, 32),
                                      (9, 64, 64, 16), (17, 96, 33, 8)])
def test_int4_kernel_matches_oracle_nonaligned(M, K, N, gs):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    packed, scale = quantize_int4(jnp.asarray(rng.normal(size=(K, N)),
                                              jnp.float32), gs)
    assert scale.shape == (K // gs, N)
    want = dequant_matmul_int4_ref(x, packed, scale)
    got = dequant_matmul_int4_pallas(x, packed, scale, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_dispatch_discriminates_by_dtype():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(2, 3, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    qw8, s8 = quantize_int8(w)
    qw4, s4 = quantize_int4(w, 16)
    y8 = dequant_matmul(x, qw8, s8)
    y4 = dequant_matmul(x, qw4, s4)
    assert y8.shape == y4.shape == (2, 3, 16)
    np.testing.assert_array_equal(np.asarray(y8),
                                  np.asarray(dequant_matmul_int8_ref(x, qw8,
                                                                     s8)))
    np.testing.assert_array_equal(np.asarray(y4),
                                  np.asarray(dequant_matmul_int4_ref(x, qw4,
                                                                     s4)))


# =========================================================== model quantizing

def test_quantize_model_structure(model_params):
    _, params = model_params
    for fmt, qdtype in (("int8", jnp.int8), ("int4", jnp.uint8)):
        qp = quantize_model(params, fmt, 16)
        # keep-list untouched
        for key in ("embed", "lm_head", "final_norm"):
            assert jax.tree.all(jax.tree.map(
                lambda a, b: bool(jnp.array_equal(a, b)),
                params[key], qp[key]))
        # stacked scanned blocks quantized in place, format by dtype
        flat = jax.tree.leaves(qp["blocks"])
        assert any(leaf.dtype == qdtype for leaf in flat)
        assert params_quant_format(qp) == fmt
        assert param_bytes(qp) < param_bytes(params)
    assert params_quant_format(params) == "bf16"
    assert quantize_model(params, "bf16") is params
    with pytest.raises(ValueError, match="supported"):
        quantize_model(params, "fp4")


def _gen(backend, prompts, n_samples=2, max_new=6):
    h = backend.start_batch(prompts, n_samples, max_new, 0.8,
                            jax.random.key(42))
    while backend.decode_step(h):
        pass
    return backend.finalize(h)


def _assert_same_results(want, got):
    for a, b in zip(want, got):
        for s1, s2 in zip(a.samples, b.samples):
            assert np.array_equal(s1, s2)
        assert a.logprobs == b.logprobs


@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_quantized_generate_bit_identical_to_dequantized(model_params, fmt):
    """Serving the quantized tree == serving its (lossy) dequantized
    reconstruction, bit for bit: the dispatch path computes exactly
    ``x @ (qw * scale)``, nothing else."""
    from repro.serving import ExecutionBackend
    model, params = model_params
    qp = quantize_model(params, fmt, 16)
    dq = dequantize_model(qp, jnp.float32)
    prompts = [((np.arange(1, 11, dtype=np.int32) * m) % CFG.vocab_size)
               for m in (1, 3)]
    want = _gen(ExecutionBackend(model, dq), prompts)
    got = _gen(ExecutionBackend(model, qp), prompts)
    _assert_same_results(want, got)


def _integerize(params, max_q):
    """Replace every quantizable dense weight with integer values whose
    per-column absmax is exactly ``max_q`` -> quantization scale is exactly
    1.0 and round-tripping is lossless (the identity-scale case)."""
    rng = np.random.default_rng(9)

    def walk(node):
        if isinstance(node, dict):
            if "w" in node and getattr(node["w"], "ndim", 0) >= 2:
                out = dict(node)
                w = rng.integers(-max_q, max_q + 1,
                                 size=node["w"].shape).astype(np.float32)
                w[..., 0, :] = max_q            # every column/group hits max_q
                if max_q == 7:                  # int4: every group of 16 rows
                    w[..., ::16, :] = max_q
                out["w"] = jnp.asarray(w, node["w"].dtype)
                return out
            return {k: walk(v) for k, v in node.items()}
        return node
    return walk(params)


@pytest.mark.parametrize("fmt,max_q", [("int8", 127), ("int4", 7)])
def test_identity_scale_generate_bit_identical_to_unquantized(model_params,
                                                              fmt, max_q):
    from repro.serving import ExecutionBackend
    model, params = model_params
    ip = _integerize(params, max_q)
    qp = quantize_model(ip, fmt, 16)
    # lossless: dequantization reproduces the integer weights exactly
    rt = dequantize_model(qp, jnp.float32)
    for a, b in zip(jax.tree.leaves(ip), jax.tree.leaves(rt)):
        assert jnp.array_equal(a, b)
    prompts = [((np.arange(1, 11, dtype=np.int32) * m) % CFG.vocab_size)
               for m in (1, 3)]
    _assert_same_results(_gen(ExecutionBackend(model, ip), prompts),
                         _gen(ExecutionBackend(model, qp), prompts))


def test_perplexity_delta_smoke_bound(model_params):
    model, params = model_params
    rng = np.random.default_rng(11)
    toks = rng.integers(0, CFG.vocab_size, size=(4, 24)).astype(np.int32)
    pos = jnp.broadcast_to(jnp.arange(23, dtype=jnp.int32)[None], (4, 23))
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:]), "positions": pos}
    base = float(model.loss(params, batch))
    for fmt, bound in (("int8", 0.05), ("int4", 0.35)):
        q = float(model.loss(quantize_model(params, fmt, 16), batch))
        assert abs(q - base) <= bound, (fmt, base, q)


# ============================================================== int8 paged KV

def test_make_cache_int8_paged_shapes_and_dense_rejection():
    cache = make_cache(CFG, 0, 0, jnp.float32,
                       paged=PagedLayout(6, 4), kv_dtype=jnp.int8)
    entry = cache["blocks"]["l0"]
    n_super = cache["blocks"]["l0"]["k"].shape[0]
    assert entry["k"].dtype == jnp.int8
    assert entry["k_scale"].shape == (n_super, 6, 4, CFG.n_kv_heads)
    assert entry["k_scale"].dtype == jnp.float32
    with pytest.raises(ValueError, match="paged"):
        make_cache(CFG, 2, 16, jnp.float32, kv_dtype=jnp.int8)


def test_int8_kv_fill_read_roundtrip_and_attention_close(model_params):
    """Quantize-on-fill + dequant-on-read through gqa_forward: the paged
    int8 path's attention output stays within int8 tolerance of the f32
    paged path on identical inputs."""
    from repro.models.attention import gqa_forward
    model, params = model_params
    p = jax.tree.map(lambda a: a[0], params["blocks"]["l0"]["attn"])
    B, S = 2, 8
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(B, S, CFG.d_model)) * 0.3, jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                 (B, S))
    table = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)

    def run(kv_dtype):
        cache = make_cache(CFG, 0, 0, jnp.float32, paged=PagedLayout(8, 4),
                           kv_dtype=kv_dtype)["blocks"]["l0"]
        # single-layer entry: strip the stacked super-block axis
        cache = jax.tree.map(lambda a: a[0], cache)
        y, new_cache = gqa_forward(p, CFG, x, positions, cache=cache,
                                   block_table=table, kv_len=12)
        xd = x[:, -1:, :]
        pd = positions[:, -1:] + 1
        yd, _ = gqa_forward(p, CFG, xd, pd, cache=new_cache,
                            block_table=table, kv_len=12)
        return y, yd, new_cache

    y32, yd32, c32 = run(None)
    y8, yd8, c8 = run(jnp.int8)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    # written slots dequantize back to the f32 cache within int8 tolerance
    filled = np.asarray(c8["pos"]) >= 0
    k_deq = np.asarray(c8["k"], np.float32) * \
        np.asarray(c8["k_scale"])[..., None]
    np.testing.assert_allclose(k_deq[filled],
                               np.asarray(c32["k"])[filled],
                               atol=0.02, rtol=0.02)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=0.05)
    np.testing.assert_allclose(np.asarray(yd8), np.asarray(yd32), atol=0.05)


def test_copy_cache_blocks_moves_scales():
    cache = make_cache(CFG, 0, 0, jnp.float32, paged=PagedLayout(6, 4),
                       kv_dtype=jnp.int8)
    k = cache["blocks"]["l0"]["k_scale"]
    cache["blocks"]["l0"]["k_scale"] = k.at[:, 0].set(3.5)
    out = copy_cache_blocks(cache, jnp.asarray([0]), jnp.asarray([5]))
    assert float(out["blocks"]["l0"]["k_scale"][0, 5, 0, 0]) == 3.5


def test_int8_kv_doubles_block_budget_at_equal_bytes(model_params):
    from repro.serving import ExecutionBackend
    model, params = model_params
    assert kv_bytes_per_token(CFG, 2) / kv_bytes_per_token(CFG, 1) >= 1.8
    b16 = ExecutionBackend(model, params, kv_blocks=8, kv_block_size=4)
    b8 = ExecutionBackend(model, params, kv_blocks=8, kv_block_size=4,
                          kv_format="int8")
    assert b16.kv_token_bytes / b8.kv_token_bytes >= 1.8
    with pytest.raises(ValueError, match="kv_blocks"):
        ExecutionBackend(model, params, kv_format="int8")
    with pytest.raises(ValueError, match="kv_format"):
        ExecutionBackend(model, params, kv_blocks=8, kv_format="fp8")


def test_int8_kv_generate_completes_and_stays_close(model_params):
    from repro.serving import ExecutionBackend
    model, params = model_params
    prompts = [((np.arange(1, 11, dtype=np.int32) * m) % CFG.vocab_size)
               for m in (1, 3)]
    want = _gen(ExecutionBackend(model, params, kv_blocks=64,
                                 kv_block_size=4), prompts)
    got = _gen(ExecutionBackend(model, params, kv_blocks=64, kv_block_size=4,
                                kv_format="int8"), prompts)
    assert all(len(r.samples) == 2 for r in got)
    # int8 KV is lossy: sampled tokens may diverge, but per-sequence mean
    # logprob stays in the same regime
    for a, b in zip(want, got):
        for la, lb in zip(a.logprobs, b.logprobs):
            assert abs(la - lb) < 1.5, (la, lb)


# =========================================== workload / telemetry / fit hooks

def test_workload_kv_bytes_and_quant_factor_tiers():
    w = Workload()
    assert w.kv_bytes_per_el == w.bytes_per_act and w.quant_factor == 1.0
    w8 = quant_workload(w, "int8", kv_format="int8")
    assert w8.bytes_per_param == 1.0 and w8.kv_bytes_per_el == 1.0
    assert w8.quant_factor == 0.65
    w4 = quant_workload(w, "int4")
    assert w4.bytes_per_param == 0.5 and w4.quant_factor == 0.45
    assert w4.kv_bytes_per_el == w.bytes_per_act
    # decode stages move fewer bytes with a lighter KV element
    dec = [s for s in decompose(CFG, w8) if s.phase == "decode"]
    dec_ref = [s for s in decompose(CFG, Workload(bytes_per_param=1.0))
               if s.phase == "decode"]
    assert sum(s.bytes_moved for s in dec) < \
        sum(s.bytes_moved for s in dec_ref)


def test_fitter_keys_quantized_kernel_records_per_format():
    from repro.qeil2.telemetry import CalibrationFitter, TraceStore
    store = TraceStore()
    for quant, eta in (("bf16", 0.8), ("int8", 0.6), ("int4", 0.5)):
        for rep in range(3):
            store.ingest({"kind": "kernel", "kernel": "dequant_matmul",
                          "rep": rep, "flops": 1e9, "bytes": 1e6,
                          "measured_us": 100.0 / eta, "roofline_us": 100.0,
                          "quant": quant, "device": "synthetic"})
    profile, _ = CalibrationFitter(store, n_bootstrap=8).fit()
    eta_keys = dict(profile.kernel_eta)
    assert set(eta_keys) == {"dequant_matmul", "dequant_matmul:int8",
                             "dequant_matmul:int4"}
    assert eta_keys["dequant_matmul"] == pytest.approx(0.8, abs=1e-6)
    assert profile.eta_for("dequant_matmul", "int8") == \
        pytest.approx(0.6, abs=1e-6)
    assert profile.eta_for("dequant_matmul", "int4") == \
        pytest.approx(0.5, abs=1e-6)
    # unmeasured quant falls back to the bare-kernel eta, then 1.0
    assert profile.eta_for("dequant_matmul", "fp8") == \
        pytest.approx(0.8, abs=1e-6)
    assert profile.eta_for("missing", "int8") == 1.0


def test_serve_trace_records_carry_quant_fields():
    from repro.qeil2.telemetry import TraceStore
    from repro.serving.scheduler import BatchRecord
    rec = BatchRecord(batch_id=0, t_s=0.0, bucket=8, n_requests=1,
                      n_sequences=2, tier_mix={"standard": 1},
                      queue_delay_s=0.0, point_index=0, energy_j=1.0,
                      latency_s=0.5, meets_caps=True, reroute=False,
                      kv_blocks_in_use=3, quant="int4", kv_format="int8",
                      weight_bytes=1234, kv_bytes_in_use=816)
    stored = TraceStore().ingest_serve(rec)
    assert stored["quant"] == "int4" and stored["kv_format"] == "int8"
    assert stored["weight_bytes"] == 1234
    assert stored["kv_bytes_in_use"] == 816


def test_synthetic_fixture_recovers_per_format_etas():
    from repro.qeil2.telemetry import CalibrationFitter
    from repro.qeil2.telemetry.synthetic import (TRUE_KERNEL_ETA,
                                                 synthetic_trace_store)
    profile, _ = CalibrationFitter(synthetic_trace_store(seed=0),
                                   n_bootstrap=0).fit()
    eta = dict(profile.kernel_eta)
    for name, truth in TRUE_KERNEL_ETA.items():
        assert name in eta
        assert abs(eta[name] - truth) < abs(1.0 - truth), (name, eta[name])
