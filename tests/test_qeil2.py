"""QEIL v2 subsystem: DASI/CPQ/Phi signal properties, the unified energy
equation's flag-gated behavior, and PGSAM optimality/determinism."""
import numpy as np
import pytest

from repro.configs.paper_models import GPT2_125M
from repro.core import (Constraints, GreedyOrchestrator, ParetoOrchestrator,
                        Workload, decompose, exhaustive_oracle,
                        homogeneous_assignment, hypervolume_2d, plan_costs)
from repro.core.devices import (EDGE_CPU, EDGE_GPU_NVIDIA, EDGE_NPU,
                                EDGE_PLATFORM)
from repro.core.safety import SafetyMonitor
from repro.models import ArchConfig
from repro.qeil2 import (PGSAM, PGSAMConfig, PGSAMOrchestrator, cpq,
                         cpq_power_factor, dasi, execute_stage_v2,
                         memory_saturation, phi, signals_for)

TINY = ArchConfig(name="tiny", arch_type="dense", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=1000)
SMALL_W = Workload(batch=1, prompt_tokens=32, decode_tokens=32, samples=4)
HETERO_W = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)
UNCONSTRAINED = Constraints(latency_budget_factor=None)


# ------------------------------------------------------------------- signals

def _stage_with_intensity(intensity: float):
    from repro.core.decomposition import Stage
    return Stage("s", "decode", 0, flops=intensity * 1e6, bytes_moved=1e6,
                 param_bytes=1e6, width=64)


def test_dasi_monotone_in_intensity_and_bounded():
    vals = [dasi(_stage_with_intensity(i), EDGE_GPU_NVIDIA)
            for i in (0.1, 1.0, 10.0, 100.0, 1e4)]
    assert all(a <= b for a, b in zip(vals, vals[1:]))
    assert all(0.0 < v <= 1.0 for v in vals)
    # saturates to exactly 1 at/above the ridge point
    ridge = EDGE_GPU_NVIDIA.ridge_point
    assert dasi(_stage_with_intensity(ridge), EDGE_GPU_NVIDIA) == \
        pytest.approx(1.0)
    assert dasi(_stage_with_intensity(10 * ridge), EDGE_GPU_NVIDIA) == 1.0


def test_dasi_msat_duality():
    """At the ridge point both subsystems are saturated; off-ridge exactly
    one of them is."""
    ridge = EDGE_NPU.ridge_point
    for mult in (0.1, 0.5, 1.0, 3.0):
        st = _stage_with_intensity(mult * ridge)
        d, m = dasi(st, EDGE_NPU), memory_saturation(st, EDGE_NPU)
        assert max(d, m) == pytest.approx(1.0)


def test_cpq_monotone_and_boundaries():
    assert cpq(0.0, EDGE_NPU) == 0.0
    vals = [cpq(b, EDGE_NPU) for b in (1e9, 5e9, 10e9, 18e9, 30e9)]
    assert all(a < b for a, b in zip(vals, vals[1:]))
    # exactly 1.0 at the headroom limit, >1 beyond it (overcommit)
    assert cpq(EDGE_NPU.mem_cap * 0.9, EDGE_NPU) == pytest.approx(1.0)
    assert cpq(EDGE_NPU.mem_cap, EDGE_NPU) > 1.0
    # the power factor clamps: overcommit doesn't explode the model
    assert cpq_power_factor(5.0) == cpq_power_factor(1.0)
    assert cpq_power_factor(0.0) == 1.0


def test_phi_decreasing_in_temperature_and_bounded():
    temps = [25.0, 45.0, 65.0, 85.0, 105.0]
    vals = [phi(t) for t in temps]
    assert all(a > b for a, b in zip(vals, vals[1:]))
    assert all(0.0 < v < 1.0 for v in vals)
    # at reference temperature the yield is 1/(1+rho_ref)
    from repro.qeil2.signals import PHI_RHO_REF
    assert phi(25.0) == pytest.approx(1.0 / (1.0 + PHI_RHO_REF))


def test_signals_for_defaults_to_ambient():
    st = _stage_with_intensity(1.0)
    sig = signals_for(st, EDGE_NPU)
    assert sig.phi == pytest.approx(phi(EDGE_NPU.t_ambient))


# ----------------------------------------------------------------- energy v2

def test_v1_path_bit_identical_with_and_without_flag():
    stages = decompose(TINY, SMALL_W)
    m = homogeneous_assignment(stages, EDGE_GPU_NVIDIA)
    a = plan_costs(stages, m, workload=SMALL_W)
    b = plan_costs(stages, m, workload=SMALL_W, model="v1")
    assert a.energy_j == b.energy_j and a.makespan_s == b.makespan_s


def test_v2_roofline_time_matches_v1():
    """v2 changes power, never time: the roofline term is shared physics."""
    stages = decompose(TINY, SMALL_W)
    m = homogeneous_assignment(stages, EDGE_NPU)
    v1 = plan_costs(stages, m, workload=SMALL_W)
    v2 = plan_costs(stages, m, workload=SMALL_W, model="v2")
    assert v2.makespan_s == pytest.approx(v1.makespan_s)


def test_v2_energy_grows_with_temperature():
    stages = decompose(TINY, SMALL_W)
    m = homogeneous_assignment(stages, EDGE_GPU_NVIDIA)
    cold = plan_costs(stages, m, workload=SMALL_W, model="v2")
    hot = plan_costs(stages, m, workload=SMALL_W, model="v2",
                     temps={EDGE_GPU_NVIDIA.name: 85.0})
    assert hot.energy_j > cold.energy_j


def test_v2_energy_grows_with_memory_pressure():
    st = _stage_with_intensity(1.0)
    lo = execute_stage_v2(st, EDGE_NPU, resident_bytes=1e9)
    hi = execute_stage_v2(st, EDGE_NPU, resident_bytes=17e9)
    assert hi.energy_j > lo.energy_j
    assert hi.time_s == pytest.approx(lo.time_s)


def test_unknown_energy_model_rejected():
    stages = decompose(TINY, SMALL_W)
    m = homogeneous_assignment(stages, EDGE_NPU)
    with pytest.raises(ValueError):
        plan_costs(stages, m, workload=SMALL_W, model="v3")


# --------------------------------------------------------------------- PGSAM

def test_pgsam_deterministic_under_fixed_seed():
    cfgs = PGSAMConfig(seed=7, iters_max=800)
    runs = []
    for _ in range(2):
        orch = PGSAMOrchestrator([EDGE_NPU, EDGE_GPU_NVIDIA], UNCONSTRAINED,
                                 config=cfgs)
        a = orch.assign(TINY, SMALL_W)
        runs.append((a.energy_j, a.latency_s,
                     tuple(sorted((k, v.name) for k, v in a.mapping.items())),
                     tuple(e.objectives for e in orch.last_result.archive)))
    assert runs[0] == runs[1]


def test_pgsam_within_5pct_of_oracle():
    """Acceptance: PGSAM energy within 5% of the exhaustive optimum on a
    <= 12-stage case (it also must never be worse than its greedy seed)."""
    devices = [EDGE_NPU, EDGE_GPU_NVIDIA]
    oracle = exhaustive_oracle(TINY, SMALL_W, devices, max_stages=12)
    greedy = GreedyOrchestrator(devices, UNCONSTRAINED).assign(TINY, SMALL_W)
    pgsam = PGSAMOrchestrator(devices, UNCONSTRAINED,
                              config=PGSAMConfig(seed=0)).assign(TINY, SMALL_W)
    assert pgsam.energy_j <= oracle.energy_j * 1.05
    assert pgsam.energy_j <= greedy.energy_j * (1 + 1e-9)


def test_pgsam_frontier_hv_ge_greedy_on_4device_fixture():
    """Acceptance: PGSAM's archive hypervolume dominates the greedy
    epsilon-constraint sweep on the heterogeneous 4-device platform."""
    greedy_pts = []
    base = GreedyOrchestrator(EDGE_PLATFORM, UNCONSTRAINED).assign(
        GPT2_125M, HETERO_W)
    greedy_pts.append((base.energy_j, base.latency_s))
    for k in range(4):
        a = GreedyOrchestrator(
            EDGE_PLATFORM,
            Constraints(latency_sla_s=base.latency_s * (0.6 + 0.2 * k))
        ).assign(GPT2_125M, HETERO_W)
        if a.mapping and a.feasible:
            greedy_pts.append((a.energy_j, a.latency_s))

    orch = PGSAMOrchestrator(EDGE_PLATFORM, UNCONSTRAINED,
                             config=PGSAMConfig(seed=0, iters_max=1500))
    frontier = orch.pareto_frontier(GPT2_125M, HETERO_W)
    pgsam_pts = [(a.energy_j, a.latency_s) for a in frontier if a.mapping]
    assert pgsam_pts

    ref = (1.1 * max(p[0] for p in greedy_pts + pgsam_pts),
           1.1 * max(p[1] for p in greedy_pts + pgsam_pts))
    assert hypervolume_2d(pgsam_pts, ref) >= hypervolume_2d(greedy_pts, ref)


def test_pgsam_memory_constraints_respected():
    tiny_mem = EDGE_NPU.with_overrides(mem_cap=1e6)
    orch = PGSAMOrchestrator([tiny_mem, EDGE_GPU_NVIDIA], UNCONSTRAINED,
                             config=PGSAMConfig(seed=0, iters_max=400))
    a = orch.assign(TINY, SMALL_W)
    stages = {s.name: s for s in decompose(TINY, SMALL_W)}
    used = {}
    for name, dev in a.mapping.items():
        used[dev.name] = used.get(dev.name, 0.0) + stages[name].param_bytes
    assert used.get(tiny_mem.name, 0.0) <= tiny_mem.mem_cap * 0.9 + 1


def test_pgsam_infeasible_when_nothing_fits():
    t1 = EDGE_NPU.with_overrides(mem_cap=1e3)
    t2 = EDGE_CPU.with_overrides(mem_cap=1e3)
    a = PGSAMOrchestrator([t1, t2], config=PGSAMConfig(seed=0)).assign(
        TINY, SMALL_W)
    assert not a.feasible and a.violations
    assert a.energy_j == float("inf")         # Optional[PlanCosts] contract


def test_pgsam_reassign_on_failure_excludes_failed_device():
    orch = PGSAMOrchestrator(EDGE_PLATFORM,
                             config=PGSAMConfig(seed=0, iters_max=400))
    a = orch.reassign_on_failure(GPT2_125M, HETERO_W,
                                 failed=["nvidia-rtx-pro-5000"])
    assert a.mapping and "nvidia-rtx-pro-5000" not in a.device_names()


def test_pgsam_respects_latency_sla():
    base = GreedyOrchestrator(EDGE_PLATFORM, UNCONSTRAINED).assign(
        GPT2_125M, HETERO_W)
    sla = base.latency_s * 1.2
    a = PGSAMOrchestrator(EDGE_PLATFORM, Constraints(latency_sla_s=sla),
                          config=PGSAMConfig(seed=0, iters_max=800)).assign(
                              GPT2_125M, HETERO_W)
    assert a.feasible and a.latency_s <= sla


def test_pgsam_v2_energy_model_with_safety_monitor():
    """Safety integration: a hot device (from the monitor's RC thermal state)
    makes v2-costed plans steer energy accounting through Phi."""
    sm = SafetyMonitor(EDGE_PLATFORM)
    # drive the GPU hot via sustained modeled power
    for _ in range(100):
        sm.thermal_step({"nvidia-rtx-pro-5000": 280.0}, 1.0)
    orch = PGSAMOrchestrator(EDGE_PLATFORM, UNCONSTRAINED,
                             config=PGSAMConfig(seed=0, iters_max=400),
                             energy_model="v2", safety=sm)
    a = orch.assign(GPT2_125M, HETERO_W)
    assert a.mapping and np.isfinite(a.energy_j)


def test_pareto_orchestrator_accepts_pgsam_engine():
    import functools
    engine = functools.partial(PGSAMOrchestrator,
                               config=PGSAMConfig(seed=0, iters_max=300))
    po = ParetoOrchestrator(EDGE_PLATFORM, engine=engine)
    front = po.frontier(GPT2_125M, HETERO_W, sample_budgets=(20,),
                        n_latency_points=4)
    assert front


def test_pgsam_coverage_min_parity_with_greedy():
    """Drop-in contract: PGSAM flags coverage-SLA violations like greedy."""
    c = Constraints(latency_budget_factor=None, coverage_min=0.999)
    w = Workload(batch=1, prompt_tokens=32, decode_tokens=32, samples=1)
    g = GreedyOrchestrator([EDGE_NPU, EDGE_GPU_NVIDIA], c).assign(TINY, w)
    p = PGSAMOrchestrator([EDGE_NPU, EDGE_GPU_NVIDIA], c,
                          config=PGSAMConfig(seed=0, iters_max=200)).assign(
                              TINY, w)
    assert not g.feasible and not p.feasible
    assert any("coverage" in v for v in p.violations)
