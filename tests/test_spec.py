"""Speculative multi-token decode (PR 8 tentpole).

* `verify_tokens` — the greedy accept rule (argmax-prefix + argmax
  correction/bonus) on hand-built logits, and distribution preservation of
  the sampled path: the emitted token's empirical marginal equals the
  tempered target distribution, deterministically and under hypothesis-
  driven logits/draft/temperature;
* greedy speculative decode is *token-identical* to plain decode (logprobs
  allclose — one verify forward reorders the matmul reductions) for both
  draft policies, dense and paged caches, on the engine path
  (`ServingEngine.generate`) and the scheduler path;
* paged-KV rollback invariants under sampled (random-length) accepts,
  including `release_sequences` mid-verify: no leak, no double-free,
  ``blocks_in_use + blocks_free == n_blocks`` after finalize;
* speculative slack is priced into admission (``request_blocks`` matches
  the blocks `start_batch` actually takes);
* `note_spec` per-batch depth notes: validation, one-shot consumption;
* `CalibrationFitter` recovers planted accept rates from "spec" trace
  records and `SpecPlanner` turns them into depth choices — full depth at a
  high fitted rate, drafting off (depth 0) at a low one;
* `NGramDraftPolicy` prompt-lookup units and the spec_workload algebra.
"""
from types import SimpleNamespace

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.models import ArchConfig, Model  # noqa: E402
from repro.qeil2 import SLATier  # noqa: E402
from repro.qeil2.telemetry import CalibrationFitter, TraceStore  # noqa: E402
from repro.qeil2.telemetry.fit import CalibrationProfile  # noqa: E402
from repro.serving import (ContinuousBatchingScheduler,  # noqa: E402
                           ExecutionBackend, SchedulerConfig, ServingEngine)
from repro.spec import (NGramDraftPolicy, SpecPlanner,  # noqa: E402
                        emission_distribution, expected_tokens_per_step,
                        make_draft_policy, spec_supported, spec_workload,
                        verify_tokens)

CFG = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
PLEN, MAX_NEW, SPEC_N = 8, 6, 3
# one verify forward vs n single-token forwards: same math, different
# matmul reduction order (f32 ~1e-6 relative per element)
LOGPROB_ATOL = 3e-5


@pytest.fixture(scope="module")
def model_params():
    model = Model(CFG, dtype=jnp.float32)
    return model, model.init(jax.random.key(0))


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab_size, size=(PLEN,)).astype(np.int32)
            for _ in range(n)]


def _backend(model, params, policy=None, paged=False, spec_n=SPEC_N):
    kw = {"spec_policy": policy, "spec_n": spec_n} if policy else {}
    if paged:
        kw.update(kv_blocks=96, kv_block_size=4)
    return ExecutionBackend(model, params, **kw)


def _run(backend, prompts, temperature, seed=0, n_samples=1):
    h = backend.start_batch(prompts, n_samples, MAX_NEW, temperature,
                            jax.random.key(seed), {})
    while backend.decode_step(h):
        pass
    return backend.finalize(h)


@pytest.fixture(scope="module")
def plain_dense(model_params):
    model, params = model_params
    return _backend(model, params)


@pytest.fixture(scope="module")
def plain_paged(model_params):
    model, params = model_params
    return _backend(model, params, paged=True)


@pytest.fixture(scope="module")
def spec_ngram_paged(model_params):
    model, params = model_params
    return _backend(model, params, NGramDraftPolicy(), paged=True)


@pytest.fixture(scope="module")
def greedy_refs(plain_dense, plain_paged):
    """Plain greedy outputs, the parity anchors (dense and paged)."""
    prompts = _prompts(3)
    return {False: _run(plain_dense, prompts, 0.0),
            True: _run(plain_paged, prompts, 0.0)}


# ========================================================= verify_tokens

def test_verify_greedy_accepts_argmax_prefix_and_corrects():
    V = 8
    logits = np.full((2, 3, V), -10.0, np.float32)
    # row 0: argmax chain 1, 2, 3; drafts (1, 2) fully accepted -> bonus 3
    logits[0, 0, 1] = 0.0
    logits[0, 1, 2] = 0.0
    logits[0, 2, 3] = 0.0
    # row 1: argmax at step 0 is 5; draft 1 rejected -> correction 5
    logits[1, 0, 5] = 0.0
    logits[1, 1, 6] = 0.0
    logits[1, 2, 7] = 0.0
    drafts = np.array([[1, 2], [1, 6]], np.int32)
    al, toks, lps = verify_tokens(jnp.asarray(logits), jnp.asarray(drafts),
                                  jax.random.key(0), 0.0, True)
    al, toks, lps = np.asarray(al), np.asarray(toks), np.asarray(lps)
    assert al.tolist() == [2, 0]
    assert toks[0, :3].tolist() == [1, 2, 3]
    assert toks[1, 0] == 5
    lsm = np.asarray(jax.nn.log_softmax(jnp.asarray(logits), axis=-1))
    np.testing.assert_allclose(lps[0, :3], lsm[0, np.arange(3), [1, 2, 3]],
                               rtol=1e-6)
    np.testing.assert_allclose(lps[1, 0], lsm[1, 0, 5], rtol=1e-6)


def test_emission_distribution_equals_target():
    rng = np.random.default_rng(1)
    p = rng.dirichlet(np.ones(16))
    for d in (0, 3, int(p.argmax())):
        np.testing.assert_allclose(emission_distribution(p, d), p,
                                   atol=1e-12)
        assert abs(emission_distribution(p, d).sum() - 1.0) < 1e-12


def _check_first_token_marginal(seed: int, d: int, temperature: float):
    """The sampled accept/reject's first emitted token must be distributed
    as the tempered target — the distribution-preservation property."""
    V, B = 12, 8000
    rng = np.random.default_rng(seed)
    row = (rng.normal(size=(V,)) * 2.0).astype(np.float32)
    logits = jnp.broadcast_to(jnp.asarray(row)[None, None], (B, 2, V))
    drafts = jnp.full((B, 1), d, jnp.int32)
    _, toks, _ = verify_tokens(logits, drafts, jax.random.key(seed),
                               temperature, False)
    first = np.asarray(toks)[:, 0]
    target = np.asarray(jax.nn.softmax(jnp.asarray(row) / temperature),
                        np.float64)
    hist = np.bincount(first, minlength=V) / B
    assert 0.5 * np.abs(hist - target).sum() < 0.05        # total variation
    # the draft token is the one a broken residual would over/under-emit
    se = np.sqrt(target[d] * (1 - target[d]) / B)
    assert abs(hist[d] - target[d]) < 5 * se + 1e-3


def test_sampled_verify_preserves_distribution():
    for seed, d, temp in ((0, 3, 1.0), (1, 0, 0.5), (2, 7, 1.7)):
        _check_first_token_marginal(seed, d, temp)


@given(seed=st.integers(0, 2 ** 16), d=st.integers(0, 11),
       temperature=st.floats(0.3, 2.0))
@settings(max_examples=10, deadline=None)
def test_sampled_verify_preserves_distribution_hyp(seed, d, temperature):
    _check_first_token_marginal(seed, d, temperature)


# ===================================================== greedy parity (pinned)

@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("kind", ["ngram", "draft"])
def test_greedy_spec_parity_engine_path(model_params, greedy_refs, kind,
                                        paged):
    model, params = model_params
    policy = make_draft_policy(kind, draft_model=model, draft_params=params)
    got = _run(_backend(model, params, policy, paged=paged), _prompts(3),
               0.0)
    for a, b in zip(greedy_refs[paged], got):
        assert all(np.array_equal(x, y)
                   for x, y in zip(a.samples, b.samples))
        np.testing.assert_allclose(a.logprobs, b.logprobs,
                                   atol=LOGPROB_ATOL)


def test_greedy_spec_parity_serving_engine(model_params):
    model, params = model_params
    policy = make_draft_policy("draft", draft_model=model,
                               draft_params=params)
    prompts = _prompts(3, seed=5)
    ref = ServingEngine(model, params, max_new_tokens=MAX_NEW,
                        temperature=0.0).generate(prompts)
    got = ServingEngine(model, params, max_new_tokens=MAX_NEW,
                        temperature=0.0,
                        backend=_backend(model, params, policy,
                                         paged=True)).generate(prompts)
    for a, b in zip(ref, got):
        assert all(np.array_equal(x, y)
                   for x, y in zip(a.samples, b.samples))
        np.testing.assert_allclose(a.logprobs, b.logprobs,
                                   atol=LOGPROB_ATOL)


class _FlatRouter:
    """Fixed-cost routing double: enough surface for the scheduler
    (resolve_tier / required_samples / route_batch)."""

    def __init__(self):
        self.tiers = {"standard": SLATier("standard", energy_weight=0.5,
                                          latency_weight=0.5)}

    def resolve_tier(self, tier):
        return self.tiers[tier] if isinstance(tier, str) else tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, **kw):
        return SimpleNamespace(
            tier=self.resolve_tier(tiers[0]), tier_counts={},
            assignment=object(), point_index=0, meets_caps=True,
            batch_costs=None, energy_j=1.0, latency_s=1.0, notes=[])


def _sched_results(backend, prompts, trace=None):
    sched = ContinuousBatchingScheduler(
        backend, _FlatRouter(),
        SchedulerConfig(max_batch_requests=4, max_new_tokens=MAX_NEW,
                        temperature=0.0),
        trace=trace)
    ids = []
    for p in prompts:
        adm = sched.submit(p, tier="standard")
        assert adm.admitted, adm.reason
        ids.append(adm.request_id)
    done = sched.run_until_idle()
    return [done[i].result for i in ids], sched


def test_greedy_spec_parity_scheduler_path(model_params, plain_paged):
    model, params = model_params
    policy = make_draft_policy("draft", draft_model=model,
                               draft_params=params)
    prompts = _prompts(4, seed=9)
    ref, _ = _sched_results(plain_paged, prompts)
    trace = TraceStore()
    got, sched = _sched_results(
        _backend(model, params, policy, paged=True), prompts, trace=trace)
    for a, b in zip(ref, got):
        assert all(np.array_equal(x, y)
                   for x, y in zip(a.samples, b.samples))
        np.testing.assert_allclose(a.logprobs, b.logprobs,
                                   atol=LOGPROB_ATOL)
    # draft == target at temperature 0: every proposal accepted, and the
    # measured outcome lands in the batch record and the "spec" trace
    for rec in sched.records:
        assert rec.spec_policy == "draft" and rec.spec_n == SPEC_N
        assert rec.spec_proposed > 0
        assert rec.spec_accepted == rec.spec_proposed
        assert rec.spec_accept_rate == 1.0
    assert trace.counts()["spec"] == len(sched.records)


# =============================================== rollback / allocator safety

def _drain_with_midflight_release(backend, seed: int):
    prompts = _prompts(3, seed=seed)
    h = backend.start_batch(prompts, 1, MAX_NEW, 0.7, jax.random.key(seed),
                            {})
    rng = np.random.default_rng(seed)
    released = False
    while backend.decode_step(h):
        if not released and rng.random() < 0.5:
            backend.release_sequences(h, [int(rng.integers(0, 3))])
            released = True
    res = backend.finalize(h)
    alloc = backend.allocator
    assert alloc.blocks_in_use == 0
    assert alloc.blocks_in_use + alloc.blocks_free == alloc.n_blocks
    for r in res:
        assert all(len(s) == MAX_NEW for s in r.samples)
        assert np.all(np.isfinite(r.logprobs))
    return h


def test_spec_rollback_allocator_clean(spec_ngram_paged):
    h = _drain_with_midflight_release(spec_ngram_paged, seed=0)
    with pytest.raises(RuntimeError):
        spec_ngram_paged.release(h)     # finalize already returned the budget


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=8, deadline=None)
def test_spec_rollback_allocator_clean_hyp(spec_ngram_paged, seed):
    _drain_with_midflight_release(spec_ngram_paged, seed)


def test_spec_slack_priced_into_admission(model_params, plain_paged,
                                          spec_ngram_paged):
    model, params = model_params
    rb_plain = plain_paged.request_blocks(PLEN, MAX_NEW, 1)
    rb_spec = spec_ngram_paged.request_blocks(PLEN, MAX_NEW, 1)
    # the verify forward's tail writes need spec_n + 1 extra slots
    assert rb_spec > rb_plain
    h = spec_ngram_paged.start_batch(_prompts(1), 1, MAX_NEW, 0.0,
                                     jax.random.key(0), {})
    assert spec_ngram_paged.allocator.blocks_in_use == rb_spec
    spec_ngram_paged.release(h)
    assert spec_ngram_paged.allocator.blocks_in_use == 0


def test_note_spec_validation_and_consumption(model_params, plain_paged,
                                              spec_ngram_paged):
    with pytest.raises(RuntimeError, match="no draft policy"):
        plain_paged.note_spec(1)
    with pytest.raises(ValueError, match="outside"):
        spec_ngram_paged.note_spec(SPEC_N + 1)
    # a noted depth applies to exactly one batch; 0 disables drafting
    spec_ngram_paged.note_spec(0)
    h0 = spec_ngram_paged.start_batch(_prompts(1), 1, MAX_NEW, 0.0,
                                      jax.random.key(0), {})
    assert h0.spec is None
    spec_ngram_paged.release(h0)
    h1 = spec_ngram_paged.start_batch(_prompts(1), 1, MAX_NEW, 0.0,
                                      jax.random.key(0), {})
    assert h1.spec is not None and h1.spec.n == SPEC_N
    spec_ngram_paged.release(h1)


# ================================================== accept-rate calibration

def _planted_trace():
    store = TraceStore()
    store.ingest({"kind": "spec", "t_s": 0.1, "policy": "ngram", "n": 4,
                  "proposed": 60, "accepted": 6, "model": "m",
                  "tier": "economy"})
    store.ingest({"kind": "spec", "t_s": 0.2, "policy": "ngram", "n": 4,
                  "proposed": 40, "accepted": 4, "model": "m",
                  "tier": "economy"})
    store.ingest({"kind": "spec", "t_s": 0.3, "policy": "draft", "n": 4,
                  "proposed": 50, "accepted": 45, "model": "m",
                  "tier": "interactive"})
    return store


def test_fitter_recovers_planted_accept_rates():
    profile, report = CalibrationFitter(_planted_trace(),
                                        n_bootstrap=0).fit()
    assert report.n_spec == 3
    # pooled per-token Bernoulli MLE: (6 + 4) / (60 + 40)
    assert profile.accept_rate_for(model="m", tier="economy",
                                   policy="ngram") == pytest.approx(0.1)
    assert profile.accept_rate_for(policy="draft") == pytest.approx(0.9)
    assert profile.accept_rate_for(policy="missing", default=0.7) == 0.7
    # fitted rates survive the artifact round-trip
    rt = CalibrationProfile.from_dict(profile.to_dict())
    assert rt.accept_rate_for(model="m", tier="economy",
                              policy="ngram") == pytest.approx(0.1)
    assert not rt.is_identity


class _CostRouter:
    """One-device v2-costed routing double with ``workload_map`` support —
    what `SpecPlanner` sweeps draft depths through."""

    def __init__(self, cfg):
        from repro.core.devices import TPU_V5E
        self.cfg = cfg
        self.device = TPU_V5E
        self.tier = SLATier("economy", energy_weight=1.0, latency_weight=0.0)

    def resolve_tier(self, tier):
        return self.tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, samples=None, prompt_tokens=None,
                    decode_tokens=None, workload_map=None):
        from repro.core.decomposition import Workload, decompose
        from repro.core.energy import plan_costs
        w = Workload(batch=len(tiers), prompt_tokens=prompt_tokens,
                     decode_tokens=decode_tokens, samples=samples or 1)
        if workload_map is not None:
            w = workload_map(w)
        stages = decompose(self.cfg, w)
        costs = plan_costs(stages, {s.name: self.device for s in stages},
                           workload=w, model="v2")
        return SimpleNamespace(tier=self.tier, tier_counts={},
                               assignment=object(), point_index=0,
                               meets_caps=True, batch_costs=costs,
                               energy_j=costs.energy_j,
                               latency_s=costs.makespan_s, notes=[])


def test_spec_planner_depth_tracks_accept_rate():
    router = _CostRouter(CFG)
    for rate, expect in ((0.02, 0), (0.95, 4)):
        planner = SpecPlanner("draft", depths=(0, 2, 4), accept_rate=rate)
        d = planner.route_batch(router, ["economy"] * 4, samples=1,
                                prompt_tokens=64, decode_tokens=64)
        assert d.spec.n == expect, (rate, d.spec)
    # the fitted profile drives the same flip through refresh()
    profile, _ = CalibrationFitter(_planted_trace(), n_bootstrap=0).fit()
    lo = SpecPlanner("ngram", depths=(0, 2, 4), model_name="m")
    lo.refresh(profile)
    assert lo.accept_rate_for("economy") == pytest.approx(0.1)
    assert lo.route_batch(router, ["economy"] * 4, samples=1,
                          prompt_tokens=64, decode_tokens=64).spec.n == 0
    hi = SpecPlanner("draft", depths=(0, 2, 4), model_name="m")
    hi.refresh(profile)
    assert hi.route_batch(router, ["interactive"] * 4, samples=1,
                          prompt_tokens=64, decode_tokens=64).spec.n == 4


# ================================================= policies + workload math

def test_ngram_prompt_lookup_and_fallback():
    pol = NGramDraftPolicy(max_ngram=3)
    h = np.array([5, 6, 7, 9, 5, 6, 7], np.int64)
    d = pol.propose([h], 2)
    assert d.shape == (1, 2) and d.dtype == np.int32
    assert d[0].tolist() == [9, 5]       # continuation of the earlier match
    h2 = np.array([1, 2, 3], np.int64)   # no repeat: repeat the last token
    assert pol.propose([h2], 3)[0].tolist() == [3, 3, 3]
    with pytest.raises(ValueError):
        NGramDraftPolicy(max_ngram=0)


def test_spec_supported_gates():
    assert spec_supported(CFG)
    import dataclasses
    assert not spec_supported(dataclasses.replace(CFG, attn_window=4))
    assert not spec_supported(dataclasses.replace(CFG, n_codebooks=2))


def test_expected_tokens_and_spec_workload():
    from repro.core.decomposition import Workload
    assert expected_tokens_per_step(0, 0.5) == 1.0
    assert expected_tokens_per_step(3, 1.0) == 4.0
    assert expected_tokens_per_step(2, 0.5) == pytest.approx(1.75)
    w = Workload(batch=2, prompt_tokens=8, decode_tokens=16, samples=1)
    assert spec_workload(w, 0, 0.9) is w            # off: untouched
    ws = spec_workload(w, 3, 0.5)
    assert ws.spec_tokens_per_step == pytest.approx(
        expected_tokens_per_step(3, 0.5))
    assert ws.spec_queries_per_step == 4.0
    assert ws.spec_query_factor == pytest.approx(
        4.0 / ws.spec_tokens_per_step)
    # defaults price exactly like the pre-speculation workload
    assert w.spec_query_factor == 1.0


def test_greedy_decode_is_rng_independent(plain_dense):
    prompts = _prompts(2, seed=3)
    a = _run(plain_dense, prompts, 0.0, seed=0)
    b = _run(plain_dense, prompts, 0.0, seed=1234)
    for x, y in zip(a, b):
        assert all(np.array_equal(s, t)
                   for s, t in zip(x.samples, y.samples))
