"""Metrics, Pareto utilities, and the HLO collective parser."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (RunMetrics, arithmetic_intensity,
                        collective_bytes_from_hlo, dominates, hypervolume_2d,
                        improvement, pareto_front, terms_from_counts)
from repro.core.devices import TPU_V5E


def _m(cov, e, lat, p):
    return RunMetrics(coverage=cov, accuracy=cov / 2, energy_j=e,
                      latency_s=lat, power_w=p, throughput_tps=1000,
                      cost_usd_per_1k=1.0)


def test_metrics_definitions():
    m = _m(0.7, 1000.0, 0.5, 100.0)
    assert m.ipw == pytest.approx(0.007)
    assert m.ece == pytest.approx(0.0007)
    assert m.ppp > 0


def test_improvement_signs():
    base = _m(0.6, 1000, 1.0, 100)
    new = _m(0.7, 500, 0.8, 50)
    d = improvement(base, new)
    assert d["coverage_pp"] == pytest.approx(10.0)
    assert d["energy_pct"] == pytest.approx(-50.0)
    assert d["ipw_pct"] > 0


# ------------------------------------------------------------------ pareto
def test_pareto_front_basic():
    pts = [(1, 5), (2, 2), (5, 1), (3, 3), (6, 6)]
    front = pareto_front(pts)
    assert sorted(front) == [0, 1, 2]


@given(st.lists(st.tuples(st.floats(0, 100), st.floats(0, 100)),
                min_size=1, max_size=30))
@settings(max_examples=100, deadline=None)
def test_pareto_front_property(pts):
    front = pareto_front(pts)
    assert front, "front never empty"
    for i in front:
        assert not any(dominates(pts[j], pts[i])
                       for j in range(len(pts)) if j != i)


def test_hypervolume_monotone():
    ref = (10.0, 10.0)
    hv1 = hypervolume_2d([(5, 5)], ref)
    hv2 = hypervolume_2d([(5, 5), (2, 8)], ref)
    hv3 = hypervolume_2d([(1, 1)], ref)
    assert hv2 >= hv1
    assert hv3 >= hv2
    assert hv1 == pytest.approx(25.0)


# ------------------------------------------------------------------ roofline
def test_terms_and_dominance():
    t = terms_from_counts(flops=197e12 * 256, bytes_moved=819e9 * 256,
                          collective_bytes=50e9 * 256 * 10, n_chips=256,
                          device=TPU_V5E)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(10.0)
    assert t.dominant == "collective"
    assert t.bound_time_s == pytest.approx(10.0)


def test_arithmetic_intensity():
    assert arithmetic_intensity(100.0, 50.0) == 2.0


HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p = f32[128,256] parameter(0)
  %ag = f32[2048,256] all-gather(%p), dimensions={0}
  %ar = bf16[64,64] all-reduce(%x), to_apply=%add
  %rs = f32[16,256] reduce-scatter(%ag), dimensions={0}
  ROOT %a2a = (f32[8,8], f32[8,8]) all-to-all(%y, %z)
  %cp = u8[1024] collective-permute(%w)
}
"""


def test_collective_parser_counts_bytes():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    assert out["all-gather"] == 2048 * 256 * 4
    assert out["all-reduce"] == 64 * 64 * 2
    assert out["reduce-scatter"] == 16 * 256 * 4
    assert out["all-to-all"] == 2 * 8 * 8 * 4
    assert out["collective-permute"] == 1024
    assert out["n_ops"] == 5
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_collective_parser_ignores_noncollectives():
    hlo = "%d = f32[4096,4096] dot(%a, %b)\n%c = f32[4,4] add(%x, %y)"
    assert collective_bytes_from_hlo(hlo)["total"] == 0
