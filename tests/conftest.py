import os
import sys

# NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and benches
# must see the single real CPU device. Only launch/dryrun.py forces 512
# placeholder devices (in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
