"""Safety framework tests: thermal RC + throttle, health/fault tolerance,
input validation, output sanity (paper Section 3.4 / Tables 10-12)."""
import numpy as np
import pytest

from repro.core import (Health, HealthMonitor, InputValidator, OutputSanitizer,
                        SafetyMonitor, ThermalModel, THETA_THROTTLE)
from repro.core.devices import EDGE_GPU_NVIDIA, EDGE_NPU, EDGE_PLATFORM


# --------------------------------------------------------------- thermal
def test_thermal_steady_state():
    tm = ThermalModel(EDGE_GPU_NVIDIA)
    for _ in range(500):
        st = tm.step(100.0, 5.0)
    expected = EDGE_GPU_NVIDIA.t_ambient + 100.0 * EDGE_GPU_NVIDIA.thermal_r
    assert abs(st.temp_c - expected) < 0.5


def test_proactive_throttle_before_hardware_limit():
    """Sustained near-peak power must trigger the theta=0.85 proactive
    throttle strictly below t_max (zero hardware throttle events)."""
    tm = ThermalModel(EDGE_GPU_NVIDIA)
    throttled = False
    for _ in range(1000):
        power = 295.0 * tm.state.throttle   # throttle feeds back into power
        st = tm.step(power, 5.0)
        throttled |= st.throttle < 1.0
    assert throttled, "throttle never engaged"
    assert st.temp_c < EDGE_GPU_NVIDIA.t_max
    assert st.events == 0, "hardware throttling fired — protection failed"


def test_cooling_restores_full_speed():
    tm = ThermalModel(EDGE_GPU_NVIDIA)
    for _ in range(300):
        tm.step(295.0, 5.0)
    for _ in range(300):
        st = tm.step(5.0, 5.0)
    assert st.throttle == 1.0
    assert st.temp_c < THETA_THROTTLE * EDGE_GPU_NVIDIA.t_max


# --------------------------------------------------------------- faults
def test_fault_recovery_within_budget_zero_loss():
    hm = HealthMonitor(EDGE_PLATFORM)
    rec = hm.fail_device("nvidia-rtx-pro-5000", now_s=10.0,
                         inflight_queries=32)
    assert rec.recovery_ms <= 100.0           # paper: redistribute <=100 ms
    assert rec.queries_lost == 0              # paper Table 11: zero loss
    assert "nvidia-rtx-pro-5000" not in hm.healthy_devices()
    assert rec.throughput_factor < 1.0


def test_total_failure_loses_queries():
    hm = HealthMonitor(EDGE_PLATFORM)
    for d in EDGE_PLATFORM[:-1]:
        hm.fail_device(d.name, 0.0)
    rec = hm.fail_device(EDGE_PLATFORM[-1].name, 0.0, inflight_queries=7)
    assert rec.queries_lost == 7


def test_degraded_latency_bound():
    hm = HealthMonitor(EDGE_PLATFORM)
    hm.fail_device("intel-ai-boost-npu", 0.0)
    # D / D_healthy = 4/3
    assert hm.degraded_latency_bound(1.0) == pytest.approx(4.0 / 3.0)


def test_recovery_reintroduces_at_half_capacity():
    hm = HealthMonitor(EDGE_PLATFORM)
    hm.fail_device("intel-ai-boost-npu", 0.0)
    hm.recover_device("intel-ai-boost-npu")
    assert hm.health["intel-ai-boost-npu"] == Health.DEGRADED
    assert hm.capacity["intel-ai-boost-npu"] == 0.5
    hm.promote_if_stable("intel-ai-boost-npu", clean_inferences=100)
    assert hm.health["intel-ai-boost-npu"] == Health.HEALTHY


def test_timeout_detector():
    hm = HealthMonitor(EDGE_PLATFORM)
    assert hm.observe_latency("intel-ai-boost-npu", observed_s=1.1,
                              expected_s=0.1)
    assert hm.health["intel-ai-boost-npu"] == Health.FAILED


def test_error_rate_detector_degrades():
    hm = HealthMonitor(EDGE_PLATFORM)
    for _ in range(50):
        hm.observe_kernel("intel-core-ultra9-285hx", ok=True)
    for _ in range(5):
        hm.observe_kernel("intel-core-ultra9-285hx", ok=False)
    assert hm.health["intel-core-ultra9-285hx"] == Health.DEGRADED


# --------------------------------------------------------------- adversarial
def test_input_validation_blocks_attacks():
    v = InputValidator(max_seq_len=128, vocab_size=1000)
    # oversized (10x context) — paper Table 12: blocked 100%
    assert not v.validate(np.zeros(1280, np.int32), 1.0).ok
    # malformed (out-of-range ids == bad encoding)
    assert not v.validate(np.array([5, -2, 7]), 2.0).ok
    assert not v.validate(np.array([5, 2000, 7]), 3.0).ok
    # empty / wrong rank
    assert not v.validate(np.zeros((2, 2), np.int32), 4.0).ok
    # legitimate input passes
    assert v.validate(np.arange(64, dtype=np.int32), 5.0).ok


def test_rate_limiting():
    v = InputValidator(max_seq_len=128, vocab_size=1000,
                       max_requests_per_s=10)
    ok = sum(v.validate(np.arange(4, dtype=np.int32), now_s=0.0).ok
             for _ in range(100))
    assert ok <= 11, "rate limiter admitted a flood"


def test_output_sanitizer_repetition_and_length():
    s = OutputSanitizer(expected_len=50)
    assert not s.check(np.zeros(101, np.int32)).ok            # length cap
    rep = np.array([7] * 95 + [1, 2, 3, 4, 5])
    assert not s.check(rep).ok                                # repetition
    healthy = np.arange(80) % 13
    assert s.check(healthy).ok


def test_safety_monitor_integration():
    sm = SafetyMonitor(EDGE_PLATFORM, max_seq_len=256, vocab_size=1000)
    th = sm.thermal_step({"nvidia-rtx-pro-5000": 295.0}, dt_s=120.0)
    assert set(th) == {d.name for d in EDGE_PLATFORM}
    t_bound, m_bound = sm.resource_bounds(0.1, 1e9)
    assert t_bound == pytest.approx(0.5)
    assert m_bound == pytest.approx(1.5e9)
