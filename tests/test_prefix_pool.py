"""Resident prefix-sharing KV pool (radix-trie block reuse across batches).

* Trie invariants — `lookup` returns the unique longest cached prefix,
  `insert`/`evict` preserve ``blocks_in_use + blocks_free == total``, and
  refcounts hit zero exactly once — deterministically and under
  hypothesis-driven random admit/hit/release/evict interleavings;
* eviction never fires on a block any admitted request holds a ref to
  (hard error naming the block and its owning prefix), and interior nodes
  never orphan children (leaf-first peeling);
* pooled decode is *bit-identical* to the non-pooled paged path (tokens +
  logprobs), cold and cache-hot, greedy and sampled, including the CoW
  partial tail block and non-uniform per-prompt sample counts — the pinned
  acceptance parity;
* admission prices cache-hot requests at marginal (post-dedup) cost and
  `capacity_free` counts evictable idle blocks, consistently: an idle hit
  charges the evictable unit its pinning consumes, a hit pinned by a live
  batch is free;
* ``pool_evict="off"`` disables reclamation: admission fails loudly when
  the budget is genuinely exhausted;
* obs counters (hits/misses/evictions/resident/hit-ratio) and scheduler
  `BatchRecord` / ``stats()`` / "serve" trace fields account the reuse.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from types import SimpleNamespace  # noqa: E402

from repro.models import ArchConfig, Model  # noqa: E402
from repro.models.cache import (kv_bytes_per_token,  # noqa: E402
                                prefix_pool_bytes)
from repro.serving import (BlockAllocator, ContinuousBatchingScheduler,  # noqa: E402
                           ExecutionBackend, PrefixPool, SchedulerConfig)
from repro.serving.prefix_pool import chunk_key  # noqa: E402

CFG = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
BS = 4


@pytest.fixture(scope="module")
def model_params():
    model = Model(CFG, dtype=jnp.float32)
    return model, model.init(jax.random.key(0))


def _prompt(n, mult=1):
    return (np.arange(1, n + 1, dtype=np.int32) * mult) % CFG.vocab_size


def _chunked(bits):
    """Prompt of ``len(bits)`` full blocks; chunk i is ``2*i + bits[i]``
    repeated — equal bit-prefixes share token-prefixes and nothing else."""
    return np.concatenate([np.full(BS, 2 * i + b, np.int32)
                           for i, b in enumerate(bits)])


def _fill_chain(pool, prompt, n_blocks):
    """Simulate what a pooled batch does for one holder: pin the cached
    chain, allocate + "fill" the rest, index it. Returns the holder's
    per-block gids (the caller releases each exactly once)."""
    a = pool.allocator
    chain = pool.acquire(prompt, n_blocks, holders=1)
    need = n_blocks - len(chain)
    pool.ensure_free(need)
    if need > a.blocks_free:
        for g in chain:
            a.free(g)
        return None
    gids = list(chain) + [a.alloc() for _ in range(need)]
    pool.insert(prompt, gids)
    return gids


# ================================================== trie (no model needed)

def test_lookup_returns_unique_longest_cached_prefix():
    a = BlockAllocator(16, BS)
    pool = PrefixPool(a)
    p_ab = _chunked([0, 0, 0])
    gids = _fill_chain(pool, p_ab, 3)
    assert pool.blocks_resident == 3
    # full walk, capped walk, divergent walk
    assert pool.lookup(p_ab, 3) == gids
    assert pool.lookup(p_ab, 2) == gids[:2]
    assert pool.lookup(_chunked([0, 0, 1]), 3) == gids[:2]
    assert pool.lookup(_chunked([1, 0, 0]), 3) == []
    # a sibling chain shares exactly the common blocks (same physical ids)
    p_div = _chunked([0, 1, 0])
    gids2 = _fill_chain(pool, p_div, 3)
    assert gids2[0] == gids[0] and gids2[1] != gids[1]
    assert pool.lookup(p_div, 3) == gids2
    # dtype canonicalization: int64 prompt resolves the int32-keyed chain
    assert pool.lookup(p_ab.astype(np.int64), 3) == gids
    assert chunk_key(p_ab, 0, BS) == chunk_key(p_ab.astype(np.int64), 0, BS)
    for g in set(gids) | set(gids2):
        assert a.refcount(g) >= 2            # holder + trie ref


def test_insert_first_writer_wins_and_duplicate_blocks_stay_plain():
    a = BlockAllocator(8, BS)
    pool = PrefixPool(a)
    p = _chunked([0, 0])
    first = _fill_chain(pool, p, 2)
    # a same-prefix sibling that prefilled its own duplicate blocks (the
    # within-batch race): insert keeps the incumbents and indexes nothing
    dup = [a.alloc(), a.alloc()]
    assert pool.insert(p, dup) == 0
    assert pool.lookup(p, 2) == first
    # the duplicates stayed plain refcounted blocks: freeing them fully
    # returns them (a trie-resident block would raise here)
    assert a.free(dup[0]) and a.free(dup[1])
    for g in first:
        a.free(g)
    assert a.blocks_in_use == pool.blocks_resident == 2


def test_evict_refuses_live_refs_and_interior_nodes():
    a = BlockAllocator(8, BS)
    pool = PrefixPool(a)
    p = _chunked([0, 0])
    root_bid, leaf_bid = _fill_chain(pool, p, 2)
    with pytest.raises(RuntimeError, match="live holder"):
        pool.evict(leaf_bid)                 # our holder ref is live
    a.free(leaf_bid)                         # release the holder's refs
    a.free(root_bid)
    with pytest.raises(RuntimeError, match="orphan"):
        pool.evict(root_bid)                 # interior: leaf-first only
    pool.evict(leaf_bid)
    pool.evict(root_bid)
    assert pool.blocks_resident == 0 and a.blocks_free == 8
    assert pool.evictions == 2
    with pytest.raises(KeyError, match="not resident"):
        pool.evict(leaf_bid)


def test_ensure_free_evicts_idle_leaves_in_lru_order():
    a = BlockAllocator(4, BS)
    pool = PrefixPool(a)
    cold = _fill_chain(pool, _chunked([0, 0]), 2)
    warm = _fill_chain(pool, _chunked([1, 1]), 2)
    for g in cold + warm:
        a.free(g)                            # all idle, all evictable
    pool.lookup(_chunked([1, 1]), 2)         # touch -> warm is most recent
    assert pool.evictable_blocks == 4
    assert pool.ensure_free(1) == 1          # peels the cold *leaf* first
    assert pool.lookup(_chunked([0, 0]), 2, touch=False) == cold[:1]
    assert pool.ensure_free(2) == 1          # then the cold root
    assert pool.lookup(_chunked([0, 0]), 2, touch=False) == []
    assert pool.lookup(_chunked([1, 1]), 2, touch=False) == warm
    assert pool.ensure_free(4) == 2          # warm chain last
    assert a.blocks_free == 4 and pool.blocks_resident == 0


def test_evict_off_policy_never_reclaims():
    a = BlockAllocator(4, BS)
    pool = PrefixPool(a, evict="off")
    gids = _fill_chain(pool, _chunked([0, 0]), 2)
    for g in gids:
        a.free(g)
    assert pool.evictable_blocks == 0        # idle but not reclaimable
    assert pool.ensure_free(4) == 0
    assert a.blocks_free == 2                # residency is permanent
    with pytest.raises(ValueError, match="eviction policy"):
        PrefixPool(a, evict="fifo")


def test_allocator_refuses_freeing_resident_blocks_under_the_pool():
    a = BlockAllocator(4, BS)
    pool = PrefixPool(a)
    bid = _fill_chain(pool, _chunked([0]), 1)[0]
    a.free(bid)                              # holder ref: fine
    with pytest.raises(RuntimeError, match="trie-resident"):
        a.free(bid)                          # trie ref: never via free()
    assert pool.owner_of(bid) == a.protected_owner(bid)
    assert "depth 1" in pool.owner_of(bid)


# ---------------------------------------------- random interleaving driver

def _drive(n_blocks, ops):
    """Random admit/release/evict interleaving; checks the pool invariants
    after every op. Holder gid lists release each block exactly once."""
    a = BlockAllocator(n_blocks, BS)
    pool = PrefixPool(a)
    holders = []                             # (prompt, n_blocks, gids)
    created = 0
    for kind, bits, arg in ops:
        if kind == "admit":
            prompt = _chunked(bits)
            before = pool.lookup(prompt, len(bits), touch=False)
            gids = _fill_chain(pool, prompt, len(bits))
            if gids is not None:
                assert gids[:len(before)] == before   # hits reuse, in order
                created += len(gids) - len(before)
                holders.append((prompt, len(bits), gids))
        elif kind == "release" and holders:
            prompt, nb, gids = holders.pop(arg % len(holders))
            for g in gids:
                a.free(g)
        else:
            pool.ensure_free(arg % (n_blocks + 1))
        # ---- invariants after every op
        assert a.blocks_in_use + a.blocks_free == n_blocks
        assert a.blocks_in_use == pool.blocks_resident
        for prompt, nb, gids in holders:
            # held chains are pinned: the walk resolves them exactly
            assert pool.lookup(prompt, nb, touch=False) == gids
            assert all(a.refcount(g) >= 2 for g in gids)
    for _, _, gids in holders:
        for g in gids:
            a.free(g)
    pool.ensure_free(n_blocks)
    assert a.blocks_free == n_blocks         # every block back exactly once
    assert pool.blocks_resident == 0
    assert pool.evictions == created         # each indexed block: out once


def test_trie_invariants_deterministic():
    _drive(8, [("admit", [0, 0], 0), ("admit", [0, 1], 0),
               ("release", [], 0), ("evict", [], 8),
               ("admit", [0, 0, 0], 0), ("release", [], 0),
               ("release", [], 0), ("evict", [], 8),
               ("admit", [1, 1], 0)])
    # budget-exhaustion skip path: 4 blocks cannot host two disjoint
    # 3-chains while one is held
    _drive(4, [("admit", [0, 0, 0], 0), ("admit", [1, 1, 1], 0),
               ("release", [], 0), ("admit", [1, 1, 1], 0)])


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 12),
       st.lists(st.tuples(st.sampled_from(["admit", "release", "evict"]),
                          st.lists(st.integers(0, 1), min_size=1,
                                   max_size=3),
                          st.integers(0, 12)),
                min_size=1, max_size=24))
def test_trie_invariants_property(n_blocks, ops):
    _drive(n_blocks, ops)


# =========================================== pooled execution: bit parity

def _gen(backend, batches, n_samples, max_new, temperature, seed=0):
    out = []
    for prompts in batches:
        h = backend.start_batch(prompts, n_samples, max_new, temperature,
                                jax.random.key(seed))
        while backend.decode_step(h):
            pass
        out.append((backend.finalize(h), h))
    return out


def _assert_results_identical(got, want):
    for (rg, _), (rw, _) in zip(got, want):
        for g, w in zip(rg, rw):
            assert g.logprobs == w.logprobs
            for sg, sw in zip(g.samples, w.samples):
                np.testing.assert_array_equal(sg, sw)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
@pytest.mark.parametrize("n_samples", [1, [2, 1]])
def test_pooled_matches_paged_bitwise_cold_and_hot(model_params, temperature,
                                                   n_samples):
    """The pinned acceptance parity: pooled tokens/logprobs are bit-equal
    to the non-pooled paged path — on a cold trie (full prefill + insert)
    and cache-hot (trie hits + tail-only prefill) — greedy and sampled,
    with the CoW partial tail block (plen=7 on bs=4) and non-uniform
    per-prompt sample counts."""
    model, params = model_params
    shared = _prompt(4)
    batches = [[np.concatenate([shared, _prompt(3, 5)]),
                np.concatenate([shared, _prompt(3, 7)])]] * 2
    plain = ExecutionBackend(model, params, kv_blocks=64, kv_block_size=BS)
    pooled = ExecutionBackend(model, params, kv_blocks=64, kv_block_size=BS,
                              kv_pool=True)
    want = _gen(plain, batches, n_samples, 4, temperature, seed=3)
    got = _gen(pooled, batches, n_samples, 4, temperature, seed=3)
    _assert_results_identical(got, want)
    # the replay actually ran cache-hot: plen=7, bs=4 -> 1 reusable block
    # per prompt ((plen-1)//bs caps the walk; the tail token stays)
    assert got[0][1].pool_hit_blocks == 0
    assert got[1][1].pool_hit_blocks == 2
    assert pooled.allocator.blocks_in_use == pooled.prefix_pool.blocks_resident


def test_pool_hits_and_prefill_bytes_saved_accounting(model_params):
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=64, kv_block_size=BS,
                          kv_pool=True)
    p = _prompt(16)
    (r1, h1), (r2, h2) = _gen(be, [[p], [p]], 1, 3, 0.0)
    ktb = be.kv_token_bytes
    assert h1.pool_hit_blocks == 0 and h1.prefill_bytes_saved == 0.0
    # warm replay reuses (16-1)//4 = 3 of the 4 full prefix blocks; only
    # the 4-token tail was prefilled
    assert h2.pool_hit_blocks == 3
    assert h2.prefill_bytes_saved == (16 - 4) * ktb
    assert prefix_pool_bytes(CFG, be.prefix_pool.blocks_resident, BS, 4) == \
        be.prefix_pool.blocks_resident * BS * kv_bytes_per_token(CFG, 4)
    _assert_results_identical([(r2, h2)], [(r1, h1)])


def test_eviction_reclaims_idle_chains_under_pressure(model_params):
    """A tight budget forces LRU eviction of an idle resident chain to fit
    a new request's tail — and the evicted prefix then misses."""
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=4, kv_block_size=BS,
                          kv_pool=True)
    pa, pb = _prompt(8), _prompt(8, 3)
    assert be.request_blocks(8, 4, 1) == 3   # 2 prefix + 1 decode block
    (_, ha), = _gen(be, [[pa]], 1, 4, 0.0)
    assert be.prefix_pool.blocks_resident == 2 and be.allocator.blocks_free == 2
    assert be.capacity_free == 4             # free + evictable idle chain
    (_, hb), = _gen(be, [[pb]], 1, 4, 0.0)
    assert hb.pool_evictions >= 1            # peeled pa's idle leaf
    assert len(be.prefix_pool.lookup(pa, 2, touch=False)) < 2
    assert be.allocator.blocks_in_use == be.prefix_pool.blocks_resident


def test_eviction_never_fires_under_live_refs_budget_fails_loudly(
        model_params):
    """While a batch holds refs on its chains, those blocks are not
    evictable; an over-budget start raises (after unwinding) instead of
    evicting under the live batch, which then completes unperturbed."""
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=5, kv_block_size=BS,
                          kv_pool=True)
    want = _gen(ExecutionBackend(model, params, kv_blocks=5,
                                 kv_block_size=BS, kv_pool=True),
                [[_prompt(8)]], 1, 4, 0.0)
    h = be.start_batch([_prompt(8)], 1, 4, 0.0, jax.random.key(0))
    assert be.prefix_pool.evictable_blocks == 0      # all chains held
    free_before = be.allocator.blocks_free
    with pytest.raises(RuntimeError, match="KV block budget exceeded"):
        be.start_batch([_prompt(8, 3)], 1, 4, 0.0, jax.random.key(0))
    assert be.allocator.blocks_free == free_before   # unwound cleanly
    while be.decode_step(h):
        pass
    got = [(be.finalize(h), h)]
    _assert_results_identical(got, want)
    assert be.capacity_free == 5             # retired: 3 free + 2 evictable


def test_evict_off_backend_raises_when_full(model_params):
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=5, kv_block_size=BS,
                          kv_pool=True, pool_evict="off")
    _gen(be, [[_prompt(8)]], 1, 4, 0.0)
    assert be.capacity_free == 3             # 2 resident forever
    # marginal price under "off": hits are free (they cost no evictable
    # headroom), so the warm replay fits where a cold one would not
    assert be.marginal_request_cost(_prompt(8), 4, 1) == 2
    _gen(be, [[_prompt(8)]], 1, 4, 0.0)      # tail-only: fits in 3 free
    with pytest.raises(RuntimeError, match="KV block budget exceeded"):
        be.start_batch([_prompt(8, 3)], 2, 4, 0.0, jax.random.key(0))


def test_kv_pool_requires_paged_cache(model_params):
    model, params = model_params
    with pytest.raises(ValueError, match="kv_pool requires the paged"):
        ExecutionBackend(model, params, kv_pool=True)


# ============================================ admission, scheduler, obs

class _StubRouter:
    def __init__(self, tiers):
        self.tiers = {t: SimpleNamespace(name=t) for t in tiers}

    def resolve_tier(self, tier):
        return self.tiers[tier] if isinstance(tier, str) else tier

    def required_samples(self, tier):
        return None

    def route_batch(self, tiers, **kw):
        return SimpleNamespace(
            tier=self.resolve_tier(tiers[0]), tier_counts={},
            assignment=object(), point_index=0, meets_caps=True,
            batch_costs=None, energy_j=1.0, latency_s=1.0, notes=[])


def test_marginal_cost_free_only_for_pinned_hits(model_params):
    """Pricing must stay consistent with `capacity_free`: an idle hit
    charges the evictable unit its pinning consumes; a hit held by a live
    batch is genuinely marginal (free)."""
    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=32, kv_block_size=BS,
                          kv_pool=True)
    p = _prompt(8)
    full = be.request_cost(8, 4, 1)
    assert be.marginal_request_cost(p, 4, 1) == full     # cold: no hits
    h = be.start_batch([p], 1, 4, 0.0, jax.random.key(0))
    # in flight: the 1 reusable block is pinned -> free; price = tail only
    assert be.marginal_request_cost(p, 4, 1) == full - 1
    while be.decode_step(h):
        pass
    be.finalize(h)
    # retired: hits idle again -> charged against evictable headroom,
    # which capacity_free now includes
    assert be.marginal_request_cost(p, 4, 1) == full
    assert be.capacity_free == 32


def test_scheduler_prices_marginally_and_records_pool_fields(model_params):
    from repro.qeil2 import TraceStore

    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=32, kv_block_size=BS,
                          kv_pool=True)
    trace = TraceStore()
    sched = ContinuousBatchingScheduler(
        be, _StubRouter(["economy"]),
        SchedulerConfig(max_batch_requests=4, max_new_tokens=3),
        trace=trace)
    p = _prompt(16)
    for _ in range(2):
        assert sched.submit(p, tier="economy", n_samples=1).admitted
        sched.run_until_idle()
    assert len(sched.records) == 2
    assert sched.records[0].pool_hit_blocks == 0
    assert sched.records[1].pool_hit_blocks == 3
    st = sched.stats()
    assert st["pool_hit_blocks"] == 3 and st["pool_evictions"] == 0
    assert st["prefill_bytes_saved"] == 12 * be.kv_token_bytes
    recs = trace.records("serve")
    assert [r["pool_hit_blocks"] for r in recs] == [0, 3]
    assert all("pool_evictions" in r for r in recs)
    assert be.allocator.blocks_in_use == be.prefix_pool.blocks_resident == 4


def test_obs_counters_track_hits_misses_resident_ratio(model_params):
    from repro.obs import make_observability

    model, params = model_params
    obs = make_observability()
    be = ExecutionBackend(model, params, kv_blocks=64, kv_block_size=BS,
                          kv_pool=True, obs=obs)
    p = _prompt(16)                          # 4 full blocks, 3 reusable
    _gen(be, [[p], [p]], 1, 3, 0.0)
    reg = obs.metrics
    assert reg.counter("serving_prefix_pool_hits_total").value() == 3
    assert reg.counter("serving_prefix_pool_misses_total").value() == 5
    assert reg.counter("serving_prefix_pool_evictions_total").value() == 0
    assert reg.gauge("serving_prefix_pool_blocks_resident").value() == 4
    h = reg.histogram("serving_prefix_pool_hit_ratio")
    assert h.sum_value() == pytest.approx(0.75)   # 0/4 then 3/4
    # the counters reproduce the analytic hit rate of the stream
    hits = reg.counter("serving_prefix_pool_hits_total").value()
    lookups = hits + reg.counter("serving_prefix_pool_misses_total").value()
    assert hits / lookups == pytest.approx(3 / 8)


def test_spec_decode_composes_with_pool(model_params):
    """Speculative decode rides the pooled cache: the draft/verify loop
    threads the resident array, and warm batches still resolve hits."""
    from repro.spec import make_draft_policy

    model, params = model_params
    be = ExecutionBackend(model, params, kv_blocks=96, kv_block_size=BS,
                          kv_pool=True,
                          spec_policy=make_draft_policy("ngram"), spec_n=2)
    p = _prompt(16)
    (r1, h1), (r2, h2) = _gen(be, [[p], [p]], 1, 5, 0.0)
    assert h2.pool_hit_blocks == 3
    _assert_results_identical([(r2, h2)], [(r1, h1)])
    assert be.allocator.blocks_in_use == be.prefix_pool.blocks_resident
