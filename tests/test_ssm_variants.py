"""SSM variant tests: split projections, kernel path, decode equivalence, and
a hypothesis property sweep on the chunk invariance of the SSD scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import ArchConfig, Model, SSMConfig
from repro.models.ssm import ssd_chunked, ssd_decode_step

BASE = ArchConfig(name="s", arch_type="ssm", n_layers=2, d_model=64,
                  n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=97,
                  rope_variant="none",
                  ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                  layer_pattern=("m",))


@given(chunk=st.sampled_from([4, 8, 16, 32]))
@settings(max_examples=4, deadline=None)
def test_ssd_chunk_size_invariance(chunk):
    """Property: the SSD output must not depend on the chunk size."""
    B, L, H, P, N = 2, 32, 2, 8, 16
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, H, N))
    Cm = jax.random.normal(ks[4], (B, L, H, N))
    y_ref, s_ref = ssd_chunked(x, dt, A, Bm, Cm, chunk=L)  # single chunk
    y, s = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_split_proj_forward_and_decode():
    cfg = BASE.with_overrides(ssm_split_proj=True)
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    assert "in_proj_z" in params["blocks"]["l0"]["ssm"]
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 97)
    cache = model.init_cache(2, 20)
    _, cache, _ = model.forward(params, {"tokens": toks}, cache)
    cur = toks
    for step in range(3):
        nt = jax.random.randint(jax.random.key(5 + step), (2, 1), 0, 97)
        pos = jnp.full((2, 1), 16 + step, jnp.int32)
        ld, cache, _ = model.forward(params, {"tokens": nt, "positions": pos},
                                     cache)
        cur = jnp.concatenate([cur, nt], 1)
        lf, _, _ = model.forward(params, {"tokens": cur})
        np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(lf[:, -1]),
                                   rtol=1e-3, atol=1e-3)


def test_initial_state_threading():
    """ssd_chunked(init_state) == running the first tokens then the rest."""
    B, L, H, P, N = 1, 16, 2, 8, 16
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, H, N))
    Cm = jax.random.normal(ks[4], (B, L, H, N))
    y_all, s_all = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y1, s1 = ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], chunk=8)
    y2, s2 = ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:],
                         chunk=8, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               rtol=2e-4, atol=2e-4)
