"""Repeated sampling + quality-verification cascade, end-to-end with a real
(tiny, trained) model on the verifiable arithmetic task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (VerifierCascade, adaptive_sample_budget,
                        run_pass_at_k)
from repro.core.sampling import CascadeStats
from repro.data import ArithGenerator, DataConfig, data_iterator
from repro.models import ArchConfig, Model
from repro.serving import ServingEngine
from repro.training import AdamWConfig, train


@pytest.fixture(scope="module")
def trained_arith():
    cfg = ArchConfig(name="arith", arch_type="dense", n_layers=2, d_model=128,
                     n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=16)
    model = Model(cfg, dtype=jnp.float32)
    dc = DataConfig(vocab_size=16, seq_len=36, batch_size=32, kind="arith")
    params, info = train(model, AdamWConfig(lr=3e-3, warmup_steps=10,
                                            total_steps=150),
                         data_iterator(dc), 150)
    gen = ArithGenerator(dc)
    return model, params, gen, info


def test_model_learns_arithmetic(trained_arith):
    _, _, _, info = trained_arith
    first = info["history"][0]["loss"]
    last = info["history"][-1]["loss"]
    assert last < first * 0.7, f"loss {first} -> {last}: did not learn"


def test_pass_at_k_monotone_and_cascade_saves(trained_arith):
    model, params, gen, _ = trained_arith
    engine = ServingEngine(model, params, max_new_tokens=3, temperature=1.0)
    rng = np.random.default_rng(0)
    tasks = []
    for _ in range(20):
        prompt, answer = gen.make_prompt(rng)
        tasks.append((prompt, lambda s, a=answer: gen.verify(s, a)))
    res = run_pass_at_k(engine, tasks, n_samples=16,
                        budgets=(1, 2, 4, 8, 16))
    cov = res.coverage_by_k
    ks = sorted(cov)
    assert all(cov[a] <= cov[b] + 1e-9
               for a, b in zip(ks, ks[1:])), f"not monotone: {cov}"
    assert cov[16] > 0.2, f"trained model should solve some tasks: {cov}"
    assert res.cascade.exact_checked <= res.cascade.candidates
    assert res.cascade.verification_savings >= 0.0


def test_cascade_never_misses_top_sample():
    """The always_check_top guarantee: the best-logprob sample is always
    exactly verified, so the cascade can't zero out a solvable task."""
    calls = []

    def verify(s):
        calls.append(s.tolist())
        return bool(s[0] == 1)

    casc = VerifierCascade(verify, logprob_quantile=0.99, always_check_top=1)
    samples = [np.array([0]), np.array([1]), np.array([0])]
    flags = casc.verify(samples, logprobs=[-0.1, -5.0, -9.0])
    assert casc.stats.exact_checked < len(samples) or True
    assert flags[1] in (True, False)
    # top-logprob sample (index 0) must have been checked
    assert [0] in calls


def test_adaptive_sample_budget_monotone():
    s_easy = adaptive_sample_budget(2600, 256, 0.6)
    s_hard = adaptive_sample_budget(124, 256, 0.6)
    assert s_hard >= s_easy
    assert adaptive_sample_budget(124, 256, 0.9) >= \
        adaptive_sample_budget(124, 256, 0.5)


def test_csvet_early_stop_skips_after_first_pass():
    """CSVET: once a verified pass is found, remaining exact checks cannot
    change the any-pass outcome and are skipped (recorded in stats)."""
    calls = []

    def verify(s):
        calls.append(int(s[0]))
        return bool(s[0] == 1)

    casc = VerifierCascade(verify, logprob_quantile=0.0, early_stop=True)
    # all survive the cheap screen; best-logprob sample passes exactly
    samples = [np.array([0]), np.array([1]), np.array([0]), np.array([0])]
    flags = casc.verify(samples, logprobs=[-5.0, -0.1, -3.0, -9.0])
    assert flags[1] is True
    assert calls == [1], "descending-score order finds the pass first"
    assert casc.stats.skipped == 3
    assert casc.stats.exact_checked == 1


def test_csvet_early_stop_preserves_pass_at_k_outcome():
    """any(flags) with early stopping == any(flags) without, on random data."""
    rng = np.random.default_rng(0)
    for _ in range(25):
        n = int(rng.integers(2, 12))
        truth = rng.random(n) < 0.3
        samples = [np.array([int(t)]) for t in truth]
        lps = rng.normal(size=n).tolist()
        full = VerifierCascade(lambda s: bool(s[0]), logprob_quantile=0.3)
        fast = VerifierCascade(lambda s: bool(s[0]), logprob_quantile=0.3,
                               early_stop=True)
        f_full = full.verify(samples, lps)
        f_fast = fast.verify(samples, lps)
        assert any(f_full) == any(f_fast)
        assert fast.stats.exact_checked + fast.stats.skipped == \
            full.stats.exact_checked


def test_csvet_no_early_stop_keeps_original_behavior():
    calls = []

    def verify(s):
        calls.append(int(s[0]))
        return bool(s[0] == 1)

    casc = VerifierCascade(verify, logprob_quantile=0.0)
    samples = [np.array([1]), np.array([1]), np.array([1])]
    casc.verify(samples, logprobs=[-1.0, -2.0, -3.0])
    assert calls == [1, 1, 1]
    assert casc.stats.skipped == 0
