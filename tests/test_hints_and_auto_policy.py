"""Sharding hints no-op safety + auto layout selection."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed import ShardingPolicy
from repro.distributed import hints
from repro.models import Model


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, object)


def test_hints_disabled_is_identity():
    hints.disable()
    x = jnp.ones((4, 8))
    assert hints.constrain(x, (None, "tensor")) is x


def test_hints_enabled_outside_mesh_graceful():
    """With no mesh in scope, constrain must not crash (dry-run safety)."""
    hints.enable()
    try:
        x = jnp.ones((4, 8))
        y = hints.constrain(x, (None, "tensor"))
        assert y.shape == x.shape
    finally:
        hints.disable()


def test_auto_policy_small_model_goes_dp_only():
    mesh = FakeMesh((16, 16), ("data", "model"))
    small = get_config("mamba2-370m")
    pol = ShardingPolicy.auto(mesh, small, global_batch=256)
    assert pol.tensor_axis is None
    assert "model" in pol.dp_axes


def test_auto_policy_large_model_keeps_tp():
    mesh = FakeMesh((16, 16), ("data", "model"))
    big = get_config("qwen2-72b")
    pol = ShardingPolicy.auto(mesh, big, global_batch=256)
    assert pol.tensor_axis == "model"


def test_auto_policy_small_batch_keeps_tp():
    """batch 32 cannot fill 256 chips DP-only — replication would waste 8x."""
    mesh = FakeMesh((16, 16), ("data", "model"))
    small = get_config("mamba2-370m")
    pol = ShardingPolicy.auto(mesh, small, global_batch=32)
    assert pol.tensor_axis == "model"
