"""Serving engine behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ArchConfig, Model
from repro.serving import ServingEngine

CFG = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


@pytest.fixture(scope="module")
def engine():
    model = Model(CFG, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    return ServingEngine(model, params, max_new_tokens=8)


def test_sample_counts_and_shapes(engine):
    prompts = [np.array([1, 2, 3], np.int32), np.array([4, 5], np.int32),
               np.array([7, 8, 9], np.int32)]
    res = engine.generate(prompts, n_samples=4)
    assert len(res) == 3
    for r in res:
        assert len(r.samples) == 4
        assert all(s.shape == (8,) for s in r.samples)
        assert all(0 <= s.min() and s.max() < CFG.padded_vocab
                   for s in r.samples)
        assert len(r.logprobs) == 4
        assert all(lp <= 0 for lp in r.logprobs)


def test_results_keep_request_order(engine):
    """Length-grouped batching must return results in input order."""
    prompts = [np.array([1] * n, np.int32) for n in (5, 2, 5, 3, 2)]
    res = engine.generate(prompts, n_samples=1)
    for p, r in zip(prompts, res):
        np.testing.assert_array_equal(r.prompt, p)


def test_deterministic_given_rng(engine):
    prompts = [np.array([1, 2, 3], np.int32)]
    a = engine.generate(prompts, n_samples=2, rng=jax.random.key(7))
    b = engine.generate(prompts, n_samples=2, rng=jax.random.key(7))
    for s1, s2 in zip(a[0].samples, b[0].samples):
        np.testing.assert_array_equal(s1, s2)
    c = engine.generate(prompts, n_samples=2, rng=jax.random.key(8))
    assert any(not np.array_equal(s1, s2)
               for s1, s2 in zip(a[0].samples, c[0].samples))


def test_temperature_zeroish_is_greedyish(engine):
    prompts = [np.array([1, 2, 3], np.int32)]
    res = engine.generate(prompts, n_samples=4, temperature=1e-4)
    first = res[0].samples[0]
    for s in res[0].samples[1:]:
        np.testing.assert_array_equal(s, first)


def test_eos_truncation():
    model = Model(CFG, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, max_new_tokens=8, eos_token=0)
    res = eng.generate([np.array([1, 2], np.int32)], n_samples=3,
                       temperature=2.0, rng=jax.random.key(1))
    for s in res[0].samples:
        assert 0 not in s  # truncated before the eos token
