"""Integration: prefill -> decode chain reproduces the full forward pass
exactly (the correctness contract behind the serving engine and every decode
dry-run shape)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ArchConfig, MLAConfig, MoEConfig, Model, SSMConfig)

CASES = {
    "dense-gqa": ArchConfig(name="d", arch_type="dense", n_layers=2,
                            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                            vocab_size=97),
    "window": ArchConfig(name="w", arch_type="dense", n_layers=2, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                         attn_window=8),
    "mla-moe": ArchConfig(
        name="m", arch_type="moe", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=97,
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_expert=32,
                      first_dense=1, capacity_factor=8.0)),
    "ssm": ArchConfig(name="s", arch_type="ssm", n_layers=2, d_model=64,
                      n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=97,
                      rope_variant="none",
                      ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                      layer_pattern=("m",)),
    "hybrid": ArchConfig(name="h", arch_type="hybrid", n_layers=8, d_model=64,
                         n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                         ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
                         moe=MoEConfig(n_experts=4, top_k=2, moe_period=2,
                                       capacity_factor=8.0),
                         layer_pattern=("m", "m", "m", "a")),
    "partial-rope": ArchConfig(name="p", arch_type="dense", n_layers=2,
                               d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                               vocab_size=97, rope_variant="partial",
                               rope_fraction=0.5, qkv_bias=True),
}


@pytest.mark.parametrize("case", list(CASES))
def test_decode_matches_full_forward(case):
    cfg = CASES[case]
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    B, S, steps = 2, 16, 3
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    cache = model.init_cache(B, S + steps)
    _, cache, _ = model.forward(params, {"tokens": toks}, cache)
    cur = toks
    for step in range(steps):
        nt = jax.random.randint(jax.random.key(10 + step), (B, 1), 0,
                                cfg.vocab_size)
        pos = jnp.full((B, 1), S + step, jnp.int32)
        ld, cache, _ = model.forward(params, {"tokens": nt, "positions": pos},
                                     cache)
        cur = jnp.concatenate([cur, nt], 1)
        lf, _, _ = model.forward(params, {"tokens": cur})
        np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(lf[:, -1]),
                                   rtol=1e-3, atol=1e-3,
                                   err_msg=f"{case} step {step}")


def test_windowed_decode_beyond_window():
    """Ring-buffer correctness: decode positions past the window must match a
    windowed full forward (tokens outside the window invisible)."""
    cfg = CASES["window"]
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    B, S = 1, 12  # window is 8
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    cache = model.init_cache(B, S + 6)
    assert cache["blocks"]["l0"]["k"].shape[2] == 8  # ring = window slots
    _, cache, _ = model.forward(params, {"tokens": toks}, cache)
    cur = toks
    for step in range(6):
        nt = jax.random.randint(jax.random.key(20 + step), (B, 1), 0,
                                cfg.vocab_size)
        pos = jnp.full((B, 1), S + step, jnp.int32)
        ld, cache, _ = model.forward(params, {"tokens": nt, "positions": pos},
                                     cache)
        cur = jnp.concatenate([cur, nt], 1)
        lf, _, _ = model.forward(params, {"tokens": cur})
        np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(lf[:, -1]),
                                   rtol=1e-3, atol=1e-3, err_msg=f"step {step}")
