"""Greedy orchestration, oracle optimality gap, Pareto frontier, safety hooks."""
import numpy as np
import pytest

from repro.core import (Constraints, GreedyOrchestrator, ParetoOrchestrator,
                        Workload, decompose, exhaustive_oracle,
                        homogeneous_assignment, pareto_front, plan_costs)
from repro.core.devices import (EDGE_CPU, EDGE_GPU_NVIDIA, EDGE_NPU,
                                EDGE_PLATFORM)
from repro.configs.paper_models import GPT2_125M
from repro.models import ArchConfig

W = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)

TINY = ArchConfig(name="tiny", arch_type="dense", n_layers=4, d_model=256,
                  n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=1000)


def test_greedy_within_5pct_of_oracle():
    """Paper Section 3.7: greedy within 5% of the exhaustive optimum."""
    wt = Workload(batch=1, prompt_tokens=32, decode_tokens=32, samples=4)
    devices = [EDGE_NPU, EDGE_GPU_NVIDIA]
    oracle = exhaustive_oracle(TINY, wt, devices, max_stages=12)
    greedy = GreedyOrchestrator(
        devices, Constraints(latency_budget_factor=None)).assign(TINY, wt)
    assert greedy.energy_j <= oracle.energy_j * 1.05


def test_heterogeneous_beats_homogeneous_simultaneously():
    """Paper Table 3's qualitative claim at latency_budget_factor=1.0."""
    orch = GreedyOrchestrator(EDGE_PLATFORM,
                              Constraints(latency_budget_factor=1.0))
    a = orch.assign(GPT2_125M, W)
    stages = decompose(GPT2_125M, W)
    best_energy = best_lat = float("inf")
    for dev in EDGE_PLATFORM:
        pc = plan_costs(stages, homogeneous_assignment(stages, dev),
                        workload=W)
        best_lat = min(best_lat, pc.makespan_s)
    gpu = plan_costs(stages, homogeneous_assignment(stages, EDGE_GPU_NVIDIA),
                     workload=W)
    assert a.latency_s <= best_lat * 1.02
    assert len(a.device_names()) >= 2, "orchestration must be heterogeneous"


def test_unconstrained_energy_matches_paper_scale():
    """Without a latency constraint the greedy reproduces the paper's ~48%
    energy reduction vs homogeneous GPU (everything memory-bound -> NPU)."""
    orch = GreedyOrchestrator(EDGE_PLATFORM,
                              Constraints(latency_budget_factor=None))
    a = orch.assign(GPT2_125M, W)
    stages = decompose(GPT2_125M, W)
    gpu = plan_costs(stages, homogeneous_assignment(stages, EDGE_GPU_NVIDIA),
                     workload=W)
    reduction = 1 - a.energy_j / gpu.energy_j
    assert reduction > 0.35, f"only {reduction:.1%} energy reduction"


def test_memory_constraints_respected():
    tiny_mem = EDGE_NPU.with_overrides(mem_cap=1e6)   # 1 MB NPU
    orch = GreedyOrchestrator([tiny_mem, EDGE_GPU_NVIDIA])
    a = orch.assign(GPT2_125M, W)
    used = {}
    stages = {s.name: s for s in decompose(GPT2_125M, W)}
    for name, dev in a.mapping.items():
        used[dev.name] = used.get(dev.name, 0.0) + stages[name].param_bytes
    for dev_name, bytes_used in used.items():
        cap = next(d.mem_cap for d in [tiny_mem, EDGE_GPU_NVIDIA]
                   if d.name == dev_name)
        assert bytes_used <= cap * 0.9 + 1


def test_infeasible_when_nothing_fits():
    tiny1 = EDGE_NPU.with_overrides(mem_cap=1e3)
    tiny2 = EDGE_CPU.with_overrides(mem_cap=1e3)
    a = GreedyOrchestrator([tiny1, tiny2]).assign(GPT2_125M, W)
    assert not a.feasible and a.violations


def test_failure_reassignment_excludes_failed_device():
    orch = GreedyOrchestrator(EDGE_PLATFORM)
    a = orch.reassign_on_failure(GPT2_125M, W,
                                 failed=["nvidia-rtx-pro-5000"])
    assert "nvidia-rtx-pro-5000" not in a.device_names()
    assert a.mapping, "must still produce an assignment"


def test_pareto_frontier_nondominated():
    po = ParetoOrchestrator(EDGE_PLATFORM)
    front = po.frontier(GPT2_125M, W, sample_budgets=(5, 20),
                        n_latency_points=4)
    assert front, "frontier must be non-empty"
    pts = [(c["energy_j"], c["latency_s"], -c["coverage"]) for c in front]
    assert sorted(pareto_front(pts)) == list(range(len(pts)))


def test_latency_budget_orders_energy():
    """Looser latency budget can only lower (or keep) minimized energy."""
    results = []
    for factor in (1.0, 2.0, None):
        a = GreedyOrchestrator(
            EDGE_PLATFORM,
            Constraints(latency_budget_factor=factor)).assign(GPT2_125M, W)
        results.append(a.energy_j)
    assert results[0] >= results[1] * 0.999 >= results[2] * 0.998


def test_infeasible_assignment_costs_are_safe():
    """Assignment with costs=None (infeasible) must not crash on the cost
    properties — they report inf so min()-style comparisons keep working."""
    tiny1 = EDGE_NPU.with_overrides(mem_cap=1e3)
    tiny2 = EDGE_CPU.with_overrides(mem_cap=1e3)
    a = GreedyOrchestrator([tiny1, tiny2]).assign(GPT2_125M, W)
    assert not a.feasible
    assert a.costs is None
    assert a.energy_j == float("inf")
    assert a.latency_s == float("inf")
