"""Telemetry & calibration subsystem: TraceStore persistence, the
CalibrationFitter's recovery of known ground truth, identity-profile parity
with the uncalibrated v2 path, measured-kernel runtime feedback, and the
signal monotonicity invariants (hypothesis-gated)."""
import json
import math

import pytest

from _hypothesis_compat import given, settings, st

from repro.configs.paper_models import GPT2_125M
from repro.core import (Constraints, SafetyMonitor, Workload, decompose,
                        homogeneous_assignment, plan_costs)
from repro.core.decomposition import Stage
from repro.core.devices import EDGE_GPU_NVIDIA, EDGE_NPU, EDGE_PLATFORM
from repro.qeil2 import (CalibratedSignalProvider, CalibrationFitter,
                         CalibrationProfile, ControlLoop, LoopConfig,
                         PGSAMConfig, PGSAMOrchestrator, SignalSet,
                         TraceStore, cpq_power_factor, phi, signals_for,
                         synthetic_trace_store)
from repro.qeil2.runtime.incremental import DeltaEvaluator
from repro.qeil2.telemetry.fit import COEF_BOUNDS, COEF_DEFAULTS, COEF_NAMES
from repro.qeil2.telemetry.provider import kernel_for_stage
from repro.qeil2.telemetry.synthetic import TRUE_COEFFS, TRUE_KERNEL_ETA

TINY = Workload(batch=1, prompt_tokens=32, decode_tokens=32, samples=4)
HETERO_W = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)
UNCONSTRAINED = Constraints(latency_budget_factor=None)


# ----------------------------------------------------------------- TraceStore

def test_trace_store_rejects_unknown_kind_and_missing_keys():
    store = TraceStore()
    with pytest.raises(ValueError, match="unknown trace record kind"):
        store.ingest({"kind": "mystery"})
    with pytest.raises(ValueError, match="missing keys"):
        store.ingest({"kind": "kernel", "kernel": "flash_attention"})
    assert len(store) == 0


def test_trace_store_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "traces.jsonl")
    store = TraceStore(path=path)            # file-backed: persists on ingest
    store.ingest({"kind": "kernel", "kernel": "k", "flops": 1.0, "bytes": 2.0,
                  "measured_us": 10.0, "roofline_us": 8.0})
    store.ingest({"kind": "dryrun", "arch": "a", "shape": "s", "flops": 3.0})
    loaded = TraceStore.load(path)
    assert len(loaded) == 2
    assert loaded.counts() == {"kernel": 1, "dryrun": 1}
    # re-opening the same path resumes from the persisted records
    resumed = TraceStore(path=path)
    assert len(resumed) == 2


def test_trace_store_ingest_dryrun_artifact_skips_errored():
    store = TraceStore()
    assert store.ingest_dryrun_artifact({"cost_analysis": {"error": "x"}}) \
        is None
    rec = store.ingest_dryrun_artifact(
        {"arch": "qwen2-72b", "shape": "train_4k",
         "cost_analysis": {"flops": 1e12, "bytes accessed": 1e9}})
    assert rec["flops"] == 1e12 and rec["bytes_accessed"] == 1e9


def test_signalset_as_dict_plain_floats():
    sig = SignalSet(dasi=0.5, msat=1.0, cpq=0.2, phi=0.9)
    d = sig.as_dict()
    assert d == {"dasi": 0.5, "msat": 1.0, "cpq": 0.2, "phi": 0.9}
    json.dumps(d)                            # structured-logging safe


# ------------------------------------------------------------ identity parity

def test_identity_provider_bit_identical_v2():
    """Acceptance: with an identity CalibrationProfile, plan_costs(model='v2')
    is bit-identical to the providerless path."""
    stages = decompose(GPT2_125M, HETERO_W)
    assign = {st.name: EDGE_PLATFORM[i % len(EDGE_PLATFORM)]
              for i, st in enumerate(stages)}
    temps = {d.name: 40.0 + 5.0 * i for i, d in enumerate(EDGE_PLATFORM)}
    base = plan_costs(stages, assign, workload=HETERO_W, model="v2",
                      temps=temps)
    ident = plan_costs(stages, assign, workload=HETERO_W, model="v2",
                       temps=temps,
                       provider=CalibratedSignalProvider(
                           CalibrationProfile.identity()))
    assert base.energy_j == ident.energy_j
    assert base.makespan_s == ident.makespan_s
    for a, b in zip(base.executions, ident.executions):
        assert a.energy_j == b.energy_j and a.time_s == b.time_s
        assert a.signals == b.signals


def test_provider_rejected_on_v1_paths():
    stages = decompose(GPT2_125M, TINY)
    assign = homogeneous_assignment(stages, EDGE_GPU_NVIDIA)
    prov = CalibratedSignalProvider()
    with pytest.raises(ValueError, match="v2"):
        plan_costs(stages, assign, workload=TINY, provider=prov)
    with pytest.raises(ValueError, match="v2"):
        PGSAMOrchestrator(EDGE_PLATFORM, provider=prov)
    with pytest.raises(ValueError, match="v2"):
        DeltaEvaluator(stages, EDGE_PLATFORM, [0] * len(stages),
                       model="v1", provider=prov)


def test_calibration_profile_roundtrip_and_hashable(tmp_path):
    profile = CalibrationProfile(
        ridge_scale=0.8, cpq_kappa=0.5, cpq_exp=2.5, phi_rho_ref=0.11,
        phi_t_slope=18.0, kernel_eta=(("flash_attention", 0.8),),
        ci=(("ridge_scale", (0.7, 0.9)),), source="fit", n_traces=10)
    path = str(tmp_path / "profile.json")
    profile.save(path)
    loaded = CalibrationProfile.load(path)
    assert loaded == profile
    assert hash(loaded) == hash(profile)     # frontier-cache key material
    assert not profile.is_identity and CalibrationProfile.identity().is_identity
    assert profile.ci_for("ridge_scale") == (0.7, 0.9)
    assert profile.eta_for("flash_attention") == 0.8
    assert profile.eta_for("unmeasured") == 1.0


# -------------------------------------------------------------------- fitting

def test_fitter_recovers_ground_truth_with_cis():
    """Acceptance: on the seeded synthetic fixture the fitted coefficients
    reduce energy-prediction RMSE vs the documented defaults, land closer to
    ground truth, and every one carries a bootstrap CI."""
    store = synthetic_trace_store(seed=0)
    profile, report = CalibrationFitter(store, n_bootstrap=40, seed=0).fit()
    assert report.rmse_fitted < report.rmse_default
    for j, name in enumerate(COEF_NAMES):
        row = report.coefficients[name]
        assert abs(row["fitted"] - TRUE_COEFFS[name]) < \
            abs(COEF_DEFAULTS[j] - TRUE_COEFFS[name])
        lo, hi = row["ci"]
        assert math.isfinite(lo) and math.isfinite(hi) and lo <= hi
    for name, true_eta in TRUE_KERNEL_ETA.items():
        row = report.kernel_eta[name]
        assert row["fitted"] == pytest.approx(true_eta, abs=0.05)
        assert row["ci"][0] <= row["fitted"] <= row["ci"][1]
    assert profile.source == "fit" and not profile.is_identity


def test_fitter_requires_usable_records():
    with pytest.raises(ValueError, match="no energy, kernel or spec"):
        CalibrationFitter(TraceStore()).fit()


def test_fitter_kernel_only_traces():
    """Kernel records alone fit the duty factors and leave the coefficient
    vector at the documented defaults."""
    store = TraceStore()
    for rep in range(5):
        store.ingest({"kind": "kernel", "kernel": "ssd_scan", "rep": rep,
                      "flops": 1e9, "bytes": 1e7,
                      "measured_us": 200.0, "roofline_us": 120.0})
    profile, report = CalibrationFitter(store, n_bootstrap=20, seed=0).fit()
    assert profile.coefficients() == COEF_DEFAULTS
    assert profile.eta_for("ssd_scan") == pytest.approx(0.6, abs=1e-9)
    assert report.n_kernel == 5 and report.n_energy == 0


# ----------------------------------------------------------- runtime feedback

def test_kernel_for_stage_mapping():
    stages = decompose(GPT2_125M, TINY)
    kernels = {st.name: kernel_for_stage(st) for st in stages}
    assert kernels["embed"] is None and kernels["lm_head"] is None
    attn_pre = [k for n, k in kernels.items()
                if ".attn" in n and n.endswith("prefill")]
    attn_dec = [k for n, k in kernels.items()
                if ".attn" in n and n.endswith("decode")]
    assert attn_pre and set(attn_pre) == {"flash_attention"}
    assert attn_dec and set(attn_dec) == {"decode_attention"}


def test_measured_eta_stretches_time_and_preserves_energy():
    """Measured kernel time substitutes the roofline: a stage backed by a
    measured kernel runs 1/eta longer with duty cycles scaled by eta; the
    dynamic energy stays put (time x activity is invariant)."""
    from repro.qeil2.energy_v2 import execute_stage_v2
    profile = CalibrationProfile(kernel_eta=(("decode_attention", 0.5),),
                                 source="fit")
    prov = CalibratedSignalProvider(profile)
    stage = next(st for st in decompose(GPT2_125M, TINY)
                 if kernel_for_stage(st) == "decode_attention")
    base = execute_stage_v2(stage, EDGE_GPU_NVIDIA)
    cal = execute_stage_v2(stage, EDGE_GPU_NVIDIA, provider=prov)
    assert cal.time_s == pytest.approx(base.time_s * 2.0)
    assert cal.signals.dasi == pytest.approx(base.signals.dasi * 0.5)
    assert cal.energy_j == pytest.approx(base.energy_j, rel=1e-9)
    # an unmeasured stage is untouched
    embed = next(st for st in decompose(GPT2_125M, TINY)
                 if st.name == "embed")
    assert execute_stage_v2(embed, EDGE_GPU_NVIDIA, provider=prov).time_s == \
        execute_stage_v2(embed, EDGE_GPU_NVIDIA).time_s


def test_delta_evaluator_parity_with_provider():
    """The incremental anneal path agrees with the full plan_costs path under
    a fitted provider (same 1e-9 contract as the uncalibrated case)."""
    store = synthetic_trace_store(seed=3, n_energy=120)
    profile, _ = CalibrationFitter(store, n_bootstrap=0, seed=0).fit()
    prov = CalibratedSignalProvider(profile)
    stages = decompose(GPT2_125M, HETERO_W)
    devices = EDGE_PLATFORM
    mapping = [i % len(devices) for i in range(len(stages))]
    temps = {d.name: 35.0 + 10.0 * i for i, d in enumerate(devices)}
    ev = DeltaEvaluator(stages, devices, mapping, workload=HETERO_W,
                        model="v2", temps=temps, provider=prov)
    for si, di in [(0, 2), (5, 3), (len(stages) - 1, 1)]:
        ev.apply(si, di)
        assign = {st.name: devices[d]
                  for st, d in zip(stages, ev.mapping)}
        full = plan_costs(stages, assign, workload=HETERO_W, model="v2",
                          temps=temps, provider=prov)
        e, mk, _ = ev.objectives()
        assert e == pytest.approx(full.energy_j, rel=1e-9)
        assert mk == pytest.approx(full.makespan_s, rel=1e-9)


def test_pgsam_with_fitted_provider_deterministic():
    store = synthetic_trace_store(seed=1, n_energy=120)
    profile, _ = CalibrationFitter(store, n_bootstrap=0, seed=0).fit()
    prov = CalibratedSignalProvider(profile)
    runs = []
    for _ in range(2):
        orch = PGSAMOrchestrator(
            EDGE_PLATFORM, UNCONSTRAINED,
            config=PGSAMConfig(seed=0, iters_max=400),
            energy_model="v2", provider=prov)
        a = orch.assign(GPT2_125M, HETERO_W)
        runs.append((a.energy_j, a.latency_s))
        assert a.mapping
    assert runs[0] == runs[1]


def test_control_loop_emits_step_records_with_signals():
    trace = TraceStore()
    safety = SafetyMonitor(EDGE_PLATFORM)
    orch = PGSAMOrchestrator(EDGE_PLATFORM, Constraints(latency_sla_s=0.15),
                             config=PGSAMConfig(seed=0, iters_max=300,
                                                incremental=True),
                             energy_model="v2", safety=safety)
    loop = ControlLoop(orch, safety, GPT2_125M, HETERO_W,
                       LoopConfig(dt_s=5.0), trace=trace)
    for _ in range(3):
        loop.step(load=1.0)
    steps = trace.records("step")
    assert len(steps) == 3
    for rec in steps:
        assert set(rec["temps"]) == {d.name for d in EDGE_PLATFORM}
        assert rec["energy_j"] > 0
        # v2-costed plans carry per-stage signal snapshots
        assert rec["signals"]
        for sig in rec["signals"].values():
            assert set(sig) == {"dasi", "msat", "cpq", "phi"}


# ----------------------------------- monotonicity invariants (property-based)

@settings(max_examples=60, deadline=None)
@given(st.floats(0.0, 5.0), st.floats(0.0, 5.0))
def test_cpq_power_factor_non_decreasing(a, b):
    lo, hi = sorted((a, b))
    assert cpq_power_factor(lo) <= cpq_power_factor(hi)
    assert cpq_power_factor(lo) >= 1.0


@settings(max_examples=60, deadline=None)
@given(st.floats(-20.0, 150.0), st.floats(-20.0, 150.0))
def test_phi_non_increasing_in_temperature(a, b):
    """Thermal yield can only fall as junctions heat (leakage grows
    monotonically with temperature)."""
    lo, hi = sorted((a, b))
    assert phi(lo) >= phi(hi)
    assert 0.0 < phi(hi) <= 1.0


@settings(max_examples=60, deadline=None)
@given(st.floats(*COEF_BOUNDS[0]),                   # ridge_scale
       st.floats(1e-3, 1.0),                         # kernel eta
       st.floats(1e-2, 1e6))                         # arithmetic intensity
def test_calibrated_dasi_in_unit_interval(ridge_scale, eta, intensity):
    """Acceptance invariant: calibrated DASI stays in [0, 1] for any fitted
    profile within the fit bounds, on every stage/device combination."""
    profile = CalibrationProfile(
        ridge_scale=ridge_scale,
        kernel_eta=(("decode_attention", eta), ("flash_attention", eta),
                    ("ssd_scan", eta)),
        source="fit")
    prov = CalibratedSignalProvider(profile)
    stage = Stage("layer00.attn+ffn.decode", "decode", 0,
                  flops=intensity * 1e6, bytes_moved=1e6, param_bytes=1e6,
                  width=64)
    for dev in (EDGE_NPU, EDGE_GPU_NVIDIA):
        d = prov.dasi(stage, dev)
        m = prov.memory_saturation(stage, dev)
        assert 0.0 <= d <= 1.0
        assert 0.0 <= m <= 1.0
        sig = prov.signals_for(stage, dev)
        assert sig.dasi == d and sig.msat == m
