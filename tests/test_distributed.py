"""Sharding policy unit tests + an actual small-mesh SPMD execution test
(subprocess, because the placeholder-device XLA flag must be set before jax
initializes — the main test process keeps the single real CPU device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import ShardingPolicy
from repro.launch.specs import adapt_config, input_specs
from repro.models import Model
from repro.models.config import INPUT_SHAPES


class FakeMesh:
    """Shape-only stand-in so the policy logic tests need no real devices."""

    def __init__(self, shape, names):
        import numpy as np
        self.axis_names = names
        self.devices = np.empty(shape, object)


def _policy(multi=False):
    if multi:
        return ShardingPolicy(FakeMesh((2, 16, 16), ("pod", "data", "model")))
    return ShardingPolicy(FakeMesh((16, 16), ("data", "model")))


def test_param_specs_divisible_everywhere():
    """Every emitted PartitionSpec must evenly divide its tensor dim for
    every assigned architecture — the invariant behind 80/80 dry-run passes."""
    import numpy as np
    for arch in ("qwen2-72b", "mamba2-370m", "granite-moe-3b-a800m",
                 "deepseek-v2-lite-16b", "jamba-v0.1-52b", "musicgen-medium"):
        cfg = get_config(arch)
        pol = _policy()
        specs = Model(cfg).param_specs()

        def check(path, leaf):
            spec = pol.param_spec(path, leaf)
            for dim, ax in zip(leaf.shape, spec):
                if ax is None:
                    continue
                n = pol.axis_sizes[ax] if isinstance(ax, str) else \
                    int(np.prod([pol.axis_sizes[a] for a in ax]))
                assert dim % n == 0, (arch, path, leaf.shape, spec)
            # no axis used twice
            used = [a for a in spec if a is not None]
            flat = []
            for a in used:
                flat.extend(a if isinstance(a, tuple) else (a,))
            assert len(flat) == len(set(flat)), (arch, path, spec)

        jax.tree_util.tree_map_with_path(check, specs)


def test_moe_experts_shard_on_model_axis():
    pol = _policy()
    cfg = get_config("deepseek-v2-lite-16b")
    specs = Model(cfg).param_specs()
    gate = specs["blocks"]["l0"]["moe"]["gate"]   # (L, E, d, ff)
    path = (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("l0"),
            jax.tree_util.DictKey("moe"), jax.tree_util.DictKey("gate"))
    spec = pol.param_spec(path, gate)
    assert spec[1] == "model", spec              # 64 experts / 16 = 4


def test_granite_experts_fall_back_to_ff_sharding():
    pol = _policy()
    cfg = get_config("granite-moe-3b-a800m")     # 40 experts: 40 % 16 != 0
    specs = Model(cfg).param_specs()
    gate = specs["blocks"]["l0"]["moe"]["gate"]
    path = (jax.tree_util.DictKey("blocks"), jax.tree_util.DictKey("l0"),
            jax.tree_util.DictKey("moe"), jax.tree_util.DictKey("gate"))
    spec = pol.param_spec(path, gate)
    assert spec[1] is None
    assert spec[2] == "data" or spec[3] == "model", spec


def test_batch_axes_divisibility():
    pol = _policy(multi=True)
    assert pol.batch_axes(256) == ("pod", "data")   # train_4k: 256 % 32 == 0
    assert pol.batch_axes(32) == ("pod", "data")    # prefill_32k
    assert pol.batch_axes(1) is None                # long_500k: replicate
    assert pol.batch_axes(24) == "pod"              # divisible by 2 only


def test_input_specs_exist_for_all_40_pairs():
    from repro.configs import ASSIGNED_ARCHS
    for arch in ASSIGNED_ARCHS:
        for shape in INPUT_SHAPES.values():
            cfg = adapt_config(get_config(arch), shape)
            batch, cache = input_specs(get_config(arch), shape)
            assert "tokens" in batch
            if shape.kind == "decode":
                assert cache, (arch, shape.name)
                if shape.name == "long_500k" and "a" in cfg.pattern:
                    assert cfg.attn_window == 4096


SMALL_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, json
    from repro.configs import get_config
    from repro.distributed import ShardingPolicy
    from repro.models import Model
    from repro.training import AdamWConfig, init_opt_state, make_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_config("granite-moe-3b-a800m").reduced()
    model = Model(cfg, dtype=jnp.float32)
    policy = ShardingPolicy(mesh)
    p_sh = policy.param_shardings(model.param_specs())
    step = make_train_step(model, AdamWConfig(lr=1e-3, total_steps=5))
    with mesh:
        params = jax.jit(model.init, out_shardings=p_sh)(jax.random.key(0))
        opt = init_opt_state(params)
        toks = jnp.zeros((8, 16), jnp.int32)
        # params/opt are already committed to their NamedShardings (params via
        # out_shardings above, opt built from the sharded params), so jit
        # infers in_shardings; an explicit (p_sh, None, None) would wrongly
        # constrain the sharded opt state to replicated and fail.
        jitted = jax.jit(step)
        losses = []
        for i in range(3):
            params, opt, m = jitted(params, opt,
                                    {"tokens": toks + i, "labels": toks})
            losses.append(float(m["loss"]))
    print(json.dumps({"losses": losses,
                      "n_devices": jax.device_count()}))
""")


def test_real_spmd_execution_small_mesh():
    """Execute 3 sharded train steps on an 8-device host mesh (subprocess)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SMALL_MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["n_devices"] == 8
    assert all(l > 0 and l == l for l in result["losses"])


def test_dryrun_artifacts_all_pass():
    """The 80 recorded dry-run artifacts (40 pairs x 2 meshes) are error-free
    and contain the roofline inputs."""
    art_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")
    if not os.path.isdir(art_dir):
        pytest.skip("dry-run artifacts not generated yet")
    files = [f for f in os.listdir(art_dir) if f.endswith(".json")
             and ("__single.json" in f or "__multi.json" in f)]
    singles = [f for f in files if f.endswith("__single.json")]
    multis = [f for f in files if f.endswith("__multi.json")]
    assert len(singles) == 40, f"expected 40 single-pod artifacts: {len(singles)}"
    assert len(multis) == 40, f"expected 40 multi-pod artifacts: {len(multis)}"
    for f in files:
        with open(os.path.join(art_dir, f)) as fh:
            art = json.load(fh)
        assert "error" not in art, (f, art.get("error"))
        assert art["cost_analysis"].get("flops", 0) > 0, f
        assert art["n_chips"] == (512 if "__multi" in f else 256)


from _hypothesis_compat import given, settings, st


@given(d_in=st.integers(8, 4096), d_out=st.integers(8, 4096),
       stacked=st.booleans())
@settings(max_examples=150, deadline=None)
def test_param_spec_divisibility_property(d_in, d_out, stacked):
    """Property: for ANY weight shape, every sharded dim divides its axis."""
    import numpy as np
    pol = _policy()
    shape = (4, d_in, d_out) if stacked else (d_in, d_out)
    leaf = jax.ShapeDtypeStruct(shape, "float32")
    keys = ["blocks", "l0", "attn", "wq"] if stacked else ["lm_head", "w"]
    path = tuple(jax.tree_util.DictKey(k) for k in keys)
    spec = pol.param_spec(path, leaf)
    for dim, ax in zip(shape, spec):
        if ax is None:
            continue
        n = pol.axis_sizes[ax] if isinstance(ax, str) else \
            int(np.prod([pol.axis_sizes[a] for a in ax]))
        assert dim % n == 0


@given(batch=st.integers(1, 1024))
@settings(max_examples=100, deadline=None)
def test_batch_axes_divisibility_property(batch):
    import numpy as np
    pol = _policy(multi=True)
    axes = pol.batch_axes(batch)
    if axes is None:
        return
    n = pol.axis_sizes[axes] if isinstance(axes, str) else \
        int(np.prod([pol.axis_sizes[a] for a in axes]))
    assert batch % n == 0 and batch >= n
