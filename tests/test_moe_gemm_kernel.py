"""Grouped expert GEMM kernel: shape/dtype sweep + block-size invariance +
integration into the MoE layer's expert compute."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.moe_gemm.moe_gemm import moe_gemm_pallas
from repro.kernels.moe_gemm.ref import moe_gemm_ref

SHAPES = [
    (4, 32, 64, 128),     # E, C, d, f
    (8, 100, 48, 96),     # non-multiple of blocks
    (2, 8, 16, 8),        # tiny
    (3, 130, 130, 70),    # all dims ragged
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm_matches_ref(shape, dtype):
    E, C, D, F = shape
    ks = jax.random.split(jax.random.key(0), 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    out = moe_gemm_pallas(x, w, block_c=32, block_f=32, block_d=32)
    ref = moe_gemm_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@given(bc=st.sampled_from([16, 32, 64]), bd=st.sampled_from([16, 32, 64]),
       bf=st.sampled_from([16, 32]))
@settings(max_examples=8, deadline=None)
def test_moe_gemm_block_invariance(bc, bd, bf):
    ks = jax.random.split(jax.random.key(3), 2)
    x = jax.random.normal(ks[0], (2, 48, 48), jnp.float32)
    w = jax.random.normal(ks[1], (2, 48, 32), jnp.float32)
    out = moe_gemm_pallas(x, w, block_c=bc, block_f=bf, block_d=bd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(moe_gemm_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


def test_moe_gemm_is_the_expert_compute():
    """The kernel computes exactly the einsum the MoE layer uses for its
    gate/up/down expert matmuls."""
    ks = jax.random.split(jax.random.key(5), 2)
    xe = jax.random.normal(ks[0], (4, 16, 32), jnp.float32)   # (E, C, d)
    gate = jax.random.normal(ks[1], (4, 32, 64), jnp.float32)  # (E, d, ff)
    want = jnp.einsum("ecd,edf->ecf", xe, gate)
    got = moe_gemm_pallas(xe, gate, block_c=16, block_f=32, block_d=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
