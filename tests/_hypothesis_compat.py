"""Optional-hypothesis shim: import ``given``/``settings``/``st`` from here.

On a bare environment (no ``hypothesis`` installed) the property tests are
collected and individually skipped instead of erroring the whole module at
import time — the tier-1 command must collect all modules cleanly and still
run every non-property test they contain.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover - env
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every strategy call
        returns an inert placeholder (never executed — the test is skipped)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*a, **k):
        return lambda fn: fn
