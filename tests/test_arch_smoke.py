"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED variant (<=2 super-block
periods, d_model<=256, <=4 experts) and runs one forward pass and one train step
on CPU, asserting output shapes and the absence of NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import Model
from repro.training import AdamWConfig, init_opt_state, make_train_step


def _make_batch(cfg, B=2, S=16, rng=None):
    rng = rng or np.random.default_rng(0)
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    toks = rng.integers(0, cfg.vocab_size, size=shape).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jnp.ones((B, 4, cfg.d_model), jnp.float32) * 0.01
    if cfg.cross_attention:
        batch["cond_memory"] = jnp.ones((B, 8, cfg.d_model), jnp.float32) * 0.01
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 256
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    batch = _make_batch(cfg)
    logits, _, aux = model.forward(params, batch)
    B, S = batch["tokens"].shape[:2]
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    assert not bool(jnp.isnan(aux)), f"{arch}: NaN aux loss"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    opt_state = init_opt_state(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                      total_steps=10)))
    batch = _make_batch(cfg)
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, f"{arch}: train step did not update parameters"


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "chatglm3-6b",
                                  "qwen2-vl-7b", "musicgen-medium",
                                  "granite-moe-3b-a800m"])
def test_reduced_decode_step(arch):
    """serve_step: prefill then one decode token, shapes + finiteness."""
    cfg = get_config(arch).reduced()
    if cfg.moe:  # avoid capacity-drop nondeterminism in the smoke check
        cfg = cfg.with_overrides(
            moe=cfg.moe.__class__(**{**cfg.moe.__dict__,
                                     "capacity_factor": 8.0}))
    model = Model(cfg, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = _make_batch(cfg, B, S)
    cache = model.init_cache(B, S + 4)
    logits, cache, _ = model.forward(params, batch, cache)
    nt_shape = (B, 1, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, 1)
    nt = jnp.zeros(nt_shape, jnp.int32)
    pos = jnp.full((B, 1), S, jnp.int32)
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], (B, 1, 3))
    b2 = {"tokens": nt, "positions": pos}
    if cfg.cross_attention:
        b2["cond_memory"] = batch["cond_memory"]
    ld, cache2, _ = model.forward(params, b2, cache)
    assert ld.shape[1] == 1
    assert not bool(jnp.isnan(ld).any())


def test_full_configs_match_assignment_sheet():
    sheet = {
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "mamba2-370m": (48, 1024, 1, 1, 0, 50280),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    }
    for arch, (L, d, H, kv, ff, V) in sheet.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, kv, ff, V), arch


def test_param_counts_near_nameplate():
    targets = {"deepseek-v2-lite-16b": 16e9, "chatglm3-6b": 6e9,
               "qwen2-vl-7b": 7.6e9, "jamba-v0.1-52b": 52e9,
               "yi-34b": 34e9, "mamba2-370m": 0.37e9, "qwen2-72b": 72e9,
               "deepseek-coder-33b": 33e9, "granite-moe-3b-a800m": 3.4e9,
               "musicgen-medium": 1.8e9}
    for arch, target in targets.items():
        n = Model(get_config(arch)).param_count()
        assert 0.8 * target < n < 1.25 * target, (arch, n, target)
