"""ControlLoop: the orchestrate -> execute -> heat -> re-orchestrate cycle.
Drift events (thermal margin, failure, recovery, CPQ saturation) trigger
bounded warm-started re-anneals; the adaptive loop finishes hot scenarios
with zero hardware-throttle events where static placement throttles."""
import pytest

from repro.configs.paper_models import GPT2_125M
from repro.core import (Constraints, DriftEvent, SafetyMonitor, Workload,
                        THETA_THROTTLE)
from repro.core.devices import EDGE_PLATFORM
from repro.qeil2 import (ControlLoop, LoopConfig, PGSAMConfig,
                         PGSAMOrchestrator)

W = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)
GPU = "nvidia-rtx-pro-5000"
SLA = Constraints(latency_sla_s=0.15)


def _orch(safety=None, iters=1200):
    return PGSAMOrchestrator(
        EDGE_PLATFORM, SLA,
        config=PGSAMConfig(seed=0, iters_max=iters, incremental=True),
        energy_model="v2", safety=safety)


def _loop(adaptive, safety, dt_s=10.0):
    return ControlLoop(_orch(safety), safety, GPT2_125M, W,
                       LoopConfig(dt_s=dt_s, reanneal_iters=300,
                                  adaptive=adaptive))


# ----------------------------------------------------------- drift plumbing

def test_safety_monitor_emits_thermal_margin_on_rising_edge_only():
    sm = SafetyMonitor(EDGE_PLATFORM)
    events = []
    sm.subscribe(events.append)
    hot = {GPU: 400.0}
    for _ in range(60):
        sm.thermal_step(hot, 5.0)
    margins = [e for e in events if e.kind == "thermal_margin"]
    assert len(margins) == 1 and margins[0].device == GPU
    limit = THETA_THROTTLE * sm.thermal[GPU].device.t_max
    assert margins[0].value > limit


def test_safety_monitor_emits_failure_and_recovery():
    sm = SafetyMonitor(EDGE_PLATFORM)
    events = []
    sm.subscribe(events.append)
    sm.health.fail_device(GPU, now_s=1.0)
    sm.health.recover_device(GPU)
    kinds = [e.kind for e in events]
    assert kinds == ["device_failed", "device_recovered"]


# -------------------------------------------------------------- closed loop

def test_adaptive_loop_sheds_hot_device_and_avoids_throttle():
    """The acceptance contrast in miniature: an exogenous heat ramp on the
    GPU. The closed loop crosses the margin once, re-anneals the GPU out,
    finishes with zero hardware-throttle events; the static baseline rides
    the same ramp into the throttle ceiling."""
    results = {}
    for adaptive in (True, False):
        sm = SafetyMonitor(EDGE_PLATFORM)
        loop = _loop(adaptive, sm)
        reannealed = False
        for i in range(30):
            r = loop.step(load=1.5, extra_power={GPU: 255.0})
            reannealed = reannealed or r.reannealed
        results[adaptive] = (sm.total_throttle_events(), reannealed,
                            loop.assignment)
    events_adaptive, reannealed, plan = results[True]
    events_static, static_reannealed, _ = results[False]
    assert events_adaptive == 0
    assert reannealed
    assert GPU not in plan.device_names()      # work moved off the hot GPU
    assert events_static >= 1
    assert not static_reannealed


def test_cooled_device_rejoins_placement():
    sm = SafetyMonitor(EDGE_PLATFORM)
    loop = _loop(True, sm)
    for _ in range(20):
        loop.step(load=1.5, extra_power={GPU: 255.0})
    assert GPU in loop._excluded
    kinds = []
    for _ in range(30):                        # ramp off: device cools
        r = loop.step(load=1.0)
        kinds += [e.kind for e in r.drift]
    assert "device_cooled" in kinds
    assert GPU not in loop._excluded
    assert GPU in loop.allowed_devices()


def test_failure_triggers_reanneal_off_dead_device():
    sm = SafetyMonitor(EDGE_PLATFORM)
    loop = _loop(True, sm)
    r = loop.step(load=1.0)
    used = loop.assignment.device_names()
    victim = used[0]
    sm.health.fail_device(victim, now_s=loop.t_s)
    r = loop.step(load=1.0)
    assert r.reannealed
    assert victim not in loop.assignment.device_names()
    # the step that executed the dying plan is lost; the re-annealed plan
    # serves from the next step on
    assert not r.served
    r = loop.step(load=1.0)
    assert r.served
    sm.health.recover_device(victim)
    r = loop.step(load=1.0)
    assert victim in loop.allowed_devices()


def test_static_loop_stops_serving_through_failure():
    sm = SafetyMonitor(EDGE_PLATFORM)
    loop = _loop(False, sm)
    loop.step(load=1.0)
    victim = loop.assignment.device_names()[0]
    sm.health.fail_device(victim, now_s=loop.t_s)
    r = loop.step(load=1.0)
    assert not r.reannealed
    assert not r.served and r.inferences == 0.0


def test_cpq_saturation_emits_drift():
    """Shrink a device until the plan's resident set crowds its headroom:
    the loop flags CPQ saturation (and the orchestrator's epoch moves)."""
    sm = SafetyMonitor(EDGE_PLATFORM)
    orch = _orch(sm, iters=400)
    loop = ControlLoop(orch, sm, GPT2_125M, W,
                       LoopConfig(dt_s=5.0, cpq_saturation=0.0,
                                  adaptive=True))
    r = loop.step(load=1.0)
    assert any(e.kind == "cpq_saturation" for e in r.drift)


def test_reanneal_is_bounded_and_warm_started():
    sm = SafetyMonitor(EDGE_PLATFORM)
    orch = _orch(sm)
    frontier = [a for a in orch.pareto_frontier(GPT2_125M, W) if a.mapping]
    warm = [a.mapping for a in frontier[:4]]
    a = orch.reanneal(GPT2_125M, W, warm, iters_max=150)
    assert a.mapping
    assert orch.last_result.iterations <= 150
    assert any("reanneal" in n for n in a.notes)
    # the re-anneal refreshed the cached frontier at the current epoch
    assert orch.pareto_frontier(GPT2_125M, W) is \
        orch.pareto_frontier(GPT2_125M, W)


def test_reanneal_patches_mappings_for_excluded_devices():
    orch = _orch(None)
    frontier = [a for a in orch.pareto_frontier(GPT2_125M, W) if a.mapping]
    warm = [a.mapping for a in frontier[:3]]
    healthy = [d.name for d in EDGE_PLATFORM if d.name != GPU]
    a = orch.reanneal(GPT2_125M, W, warm, healthy=healthy, iters_max=200)
    assert a.mapping
    assert GPU not in a.device_names()
