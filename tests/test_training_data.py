"""Training substrate + data pipeline tests."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import (ArithGenerator, CopyGenerator, DataConfig,
                        MarkovGenerator, data_iterator)
from repro.models import ArchConfig, Model
from repro.training import (AdamWConfig, init_opt_state, latest_checkpoint,
                            lr_schedule, make_train_step, restore_checkpoint,
                            save_checkpoint, train)

TINY = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)


# ------------------------------------------------------------------ optimizer
def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(5e-4)
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
    end = float(lr_schedule(cfg, jnp.asarray(100)))
    assert end == pytest.approx(1e-4, rel=1e-3)


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4))}
    huge = {"w": jnp.full((4, 4), 1e6)}
    state = init_opt_state(params)
    new, state, metrics = __import__(
        "repro.training.optimizer", fromlist=["adamw_update"]
    ).adamw_update(cfg, params, huge, state)
    assert float(metrics["grad_norm"]) > 1e6
    assert np.all(np.isfinite(np.asarray(new["w"])))


def test_loss_decreases_on_markov():
    model = Model(TINY, dtype=jnp.float32)
    dc = DataConfig(vocab_size=64, seq_len=32, batch_size=16, kind="markov")
    params, info = train(model, AdamWConfig(lr=2e-3, warmup_steps=5,
                                            total_steps=80),
                         data_iterator(dc), 80)
    h = info["history"]
    assert h[-1]["loss"] < h[0]["loss"] * 0.97


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation must be numerically equivalent to the full
    batch (same mean loss/gradient)."""
    model = Model(TINY, dtype=jnp.float32)
    params = model.init(jax.random.key(0))
    opt = init_opt_state(params)
    dc = DataConfig(vocab_size=64, seq_len=16, batch_size=8, kind="markov")
    batch = next(data_iterator(dc))
    s1 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    s4 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3), microbatches=4))
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip():
    model = Model(TINY, dtype=jnp.float32)
    params = model.init(jax.random.key(1))
    opt = init_opt_state(params)
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 7, params, opt)
        assert latest_checkpoint(d) == path
        step, p2, o2 = restore_checkpoint(path, model.param_specs(),
                                          opt_template=opt)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected():
    model = Model(TINY, dtype=jnp.float32)
    params = model.init(jax.random.key(1))
    other = Model(TINY.with_overrides(d_model=128), dtype=jnp.float32)
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 1, params)
        with pytest.raises(ValueError):
            restore_checkpoint(path, other.param_specs())


# ------------------------------------------------------------------ data
def test_data_determinism():
    dc = DataConfig(vocab_size=64, seq_len=32, batch_size=4, kind="markov",
                    seed=3)
    a = MarkovGenerator(dc).batch(5)
    b = MarkovGenerator(dc).batch(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = MarkovGenerator(dc).batch(6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_labels_are_shifted_tokens():
    dc = DataConfig(vocab_size=64, seq_len=32, batch_size=4, kind="copy")
    b = CopyGenerator(dc).batch(0)
    # tokens[t+1] == labels[t] by construction of _finish
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


@given(digits=st.sampled_from([1, 2]), seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_arith_verify_roundtrip(digits, seed):
    dc = DataConfig(vocab_size=16, seq_len=32, batch_size=2, kind="arith")
    gen = ArithGenerator(dc, digits=digits)
    rng = np.random.default_rng(seed)
    prompt, answer = gen.make_prompt(rng)
    good = np.array(gen._digits_of(answer), np.int32)
    assert gen.verify(good, answer)
    assert not gen.verify((good + 1) % gen.base, answer)


def test_multicodebook_batches():
    dc = DataConfig(vocab_size=64, seq_len=16, batch_size=2, kind="markov",
                    n_codebooks=4)
    b = MarkovGenerator(dc).batch(0)
    assert b["tokens"].shape == (2, 16, 4)
    assert b["labels"].shape == (2, 16, 4)
