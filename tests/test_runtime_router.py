"""ParetoRouter: SLA tiers scalarize to archive operating points; the
frontier cache makes repeated routing cheap and epoch-invalidatable; the
RoutedServingEngine adapter makes ServingEngine placement frontier-driven
per generate call."""
import numpy as np
import pytest

from repro.configs.paper_models import GPT2_125M
from repro.core import Constraints, Workload
from repro.core.devices import EDGE_PLATFORM
from repro.models import ArchConfig
from repro.qeil2 import (PGSAMConfig, PGSAMOrchestrator, ParetoRouter,
                         SLATier, default_tiers)

HETERO_W = Workload(batch=1, prompt_tokens=128, decode_tokens=256, samples=20)
UNCONSTRAINED = Constraints(latency_budget_factor=None)


@pytest.fixture(scope="module")
def orch():
    return PGSAMOrchestrator(
        EDGE_PLATFORM, UNCONSTRAINED,
        config=PGSAMConfig(seed=0, iters_max=1500, incremental=True),
        energy_model="v2")


@pytest.fixture(scope="module")
def router(orch):
    placed = [a for a in orch.pareto_frontier(GPT2_125M, HETERO_W)
              if a.mapping]
    base = min(a.latency_s for a in placed) / 0.9
    return ParetoRouter(orch, GPT2_125M, HETERO_W,
                        tiers=default_tiers(base))


def test_three_tiers_route_to_two_plus_distinct_points(router):
    """Acceptance: >=3 SLA tiers map to >=2 distinct archive operating
    points on the 4-device edge fixture."""
    decisions = router.route_all()
    assert len(decisions) >= 3
    assert len({d.point_index for d in decisions.values()}) >= 2


def test_tier_caps_are_respected(router):
    for name, d in router.route_all().items():
        assert d.meets_caps, name
        tier = d.tier
        if tier.latency_p99_s is not None:
            assert d.latency_s <= tier.latency_p99_s
        if tier.energy_cap_w is not None:
            assert d.avg_power_w <= tier.energy_cap_w


def test_tier_weights_pull_along_the_frontier(router):
    lat = router.route(SLATier("lat", energy_weight=0.0, latency_weight=1.0))
    eco = router.route(SLATier("eco", energy_weight=1.0, latency_weight=0.0))
    assert lat.latency_s <= eco.latency_s
    assert eco.energy_j <= lat.energy_j
    # the extremes of the archive, by construction of the scalarization
    front = router.frontier
    assert eco.energy_j == pytest.approx(min(a.energy_j for a in front))
    assert lat.latency_s == pytest.approx(min(a.latency_s for a in front))


def test_impossible_caps_degrade_to_best_effort(router):
    d = router.route(SLATier("impossible", latency_p99_s=1e-9,
                             energy_cap_w=1e-9))
    assert not d.meets_caps
    assert d.assignment.mapping
    assert any("best-effort" in n for n in d.notes)


def test_min_quality_raises_sampling_budget(router):
    d = router.route(SLATier("quality", min_quality=0.95,
                             energy_weight=1.0))
    assert d.quality is not None and d.quality < 0.95
    assert d.samples is not None and d.samples > HETERO_W.samples


def test_frontier_cache_hit_and_epoch_invalidation(orch, router):
    f1 = orch.pareto_frontier(GPT2_125M, HETERO_W)
    f2 = orch.pareto_frontier(GPT2_125M, HETERO_W)
    assert f1 is f2                       # memoized, no second anneal
    epoch = orch.health_epoch
    orch.invalidate_frontier()
    assert orch.health_epoch == epoch + 1
    f3 = orch.pareto_frontier(GPT2_125M, HETERO_W)
    assert f3 is not f1                   # fresh anneal after invalidation
    # the router transparently re-pulls on its next route
    d = router.route("economy")
    assert d.assignment in router.frontier
    assert router._epoch == orch.health_epoch


def test_on_drift_invalidates(orch):
    from repro.core import DriftEvent
    f1 = orch.pareto_frontier(GPT2_125M, HETERO_W)
    orch.on_drift(DriftEvent(0.0, "nvidia-rtx-pro-5000", "thermal_margin"))
    assert orch.pareto_frontier(GPT2_125M, HETERO_W) is not f1


def test_healthy_subset_routes_without_excluded_device(orch):
    healthy = [d.name for d in EDGE_PLATFORM
               if d.name != "nvidia-rtx-pro-5000"]
    r = ParetoRouter(orch, GPT2_125M, HETERO_W,
                     tiers=[SLATier("eco", energy_weight=1.0)],
                     healthy=healthy)
    d = r.route("eco")
    assert "nvidia-rtx-pro-5000" not in d.assignment.device_names()


# ------------------------------------------------- serving engine adapter

def test_routed_serving_engine_places_per_generate():
    import jax
    import jax.numpy as jnp
    from repro.models import Model
    from repro.serving import ServingEngine
    from repro.qeil2 import RoutedServingEngine

    cfg = ArchConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64)
    w = Workload(batch=2, prompt_tokens=3, decode_tokens=4, samples=2)
    orch = PGSAMOrchestrator(
        EDGE_PLATFORM, UNCONSTRAINED,
        config=PGSAMConfig(seed=0, iters_max=300, incremental=True))
    placed = [a for a in orch.pareto_frontier(cfg, w) if a.mapping]
    base = min(a.latency_s for a in placed) / 0.9
    router = ParetoRouter(orch, cfg, w, tiers=default_tiers(base))

    model = Model(cfg, dtype=jnp.float32)
    engine = ServingEngine(model, params=model.init(jax.random.key(0)),
                           max_new_tokens=4)
    routed = RoutedServingEngine(engine, router, default_tier="economy")
    prompts = [np.array([1, 2, 3], np.int32), np.array([4, 5, 6], np.int32)]
    res = routed.generate(prompts, n_samples=2)
    assert len(res) == 2 and all(len(r.samples) == 2 for r in res)
    assert len(routed.decisions) == 1
    assert engine.last_placement is routed.decisions[0].assignment
    # a second call under a different tier re-routes
    routed.generate(prompts, tier="interactive", n_samples=1)
    assert len(routed.decisions) == 2
    assert routed.decisions[1].tier.name == "interactive"
    assert len(engine.placements) == 2


def test_routed_engine_requires_some_tier():
    class _Engine:                    # placement hook only, no jax needed
        placement_provider = None
    r = object.__new__(ParetoRouter)  # never routed before raising
    from repro.qeil2 import RoutedServingEngine
    routed = RoutedServingEngine(_Engine(), r)
    with pytest.raises(ValueError):
        routed.generate([np.array([1], np.int32)])
